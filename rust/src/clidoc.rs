//! The CLI's single source of truth: one flag table that renders both
//! the `dssfn` usage text ([`usage`]) and the committed flag reference
//! `docs/CLI.md` ([`markdown`], printed by `dssfn cli-doc`).
//!
//! Because both artifacts are generated from [`FLAGS`] / [`COMMANDS`] /
//! [`CONFLICTS`], the help text and the documentation cannot drift:
//! `rust/tests/cli.rs` pins the committed `docs/CLI.md` byte-for-byte
//! against [`markdown`], so adding a flag without regenerating the doc
//! fails CI. Regenerate with:
//!
//! ```text
//! cargo run --release -- cli-doc > docs/CLI.md
//! ```

/// The subcommands and their one-line purposes.
pub const COMMANDS: &[(&str, &str)] = &[
    ("train", "train the decentralized SSFN (session-driven: typed events, checkpoints, budgets)"),
    ("serve", "coordinate a real multi-process run over TCP (workers join with `worker`)"),
    ("worker", "run one shard's node process against a `serve` coordinator"),
    ("central", "train the centralized baseline on the full data"),
    ("sweep", "degree sweep over the circular topology (Fig. 4)"),
    ("datasets", "list registered datasets"),
    ("info", "show the resolved configuration without training"),
    ("cli-doc", "print the generated CLI reference (docs/CLI.md)"),
];

/// One CLI flag: its value shape (empty = boolean switch), the commands
/// it affects, its default, its `--config` file key, and a one-line
/// description.
pub struct Flag {
    /// Flag name including the leading `--`.
    pub name: &'static str,
    /// Value placeholder (`""` for bare switches).
    pub value: &'static str,
    /// Space-separated commands the flag affects.
    pub commands: &'static str,
    /// Default when the flag is absent (`""` = none / off).
    pub default: &'static str,
    /// The `section.key` a `--config` TOML file uses for the same knob
    /// (`""` = CLI-only, no file equivalent).
    pub toml: &'static str,
    /// One-line description.
    pub help: &'static str,
}

/// Every flag the binary accepts — the one table the usage text and
/// `docs/CLI.md` are rendered from.
pub const FLAGS: &[Flag] = &[
    Flag { name: "--config", value: "FILE", commands: "train serve worker central sweep info", default: "",
        toml: "", help: "load a TOML experiment file first; later flags override it" },
    Flag { name: "--dataset", value: "KEY", commands: "train serve worker central sweep info", default: "quickstart",
        toml: "experiment.dataset", help: "dataset registry key (see `dssfn datasets`)" },
    Flag { name: "--seed", value: "S", commands: "train serve worker central sweep info", default: "0xD55F",
        toml: "experiment.seed", help: "master seed: data, random matrices, comm schedules, stragglers" },
    Flag { name: "--layers", value: "L", commands: "train serve worker central sweep info", default: "20 (5 for -small presets)",
        toml: "model.layers", help: "SSFN depth L" },
    Flag { name: "--admm-iters", value: "K", commands: "train serve worker central sweep info", default: "100 (50 for -small presets)",
        toml: "admm.iterations", help: "ADMM iterations per layer K" },
    Flag { name: "--mu0", value: "F", commands: "train serve worker central sweep info", default: "0.01",
        toml: "admm.mu0", help: "Lagrangian mu for the input-layer solve" },
    Flag { name: "--mul", value: "F", commands: "train serve worker central sweep info", default: "1.0",
        toml: "admm.mul", help: "Lagrangian mu for the hidden-layer solves" },
    Flag { name: "--nodes", value: "M", commands: "train serve worker sweep info", default: "20 (10 for -small presets)",
        toml: "network.nodes", help: "worker count M" },
    Flag { name: "--degree", value: "D", commands: "train serve worker sweep info", default: "4 (2 for -small presets)",
        toml: "network.degree", help: "circular-topology degree d" },
    Flag { name: "--degrees", value: "1,2,...", commands: "sweep", default: "1..=M/2",
        toml: "", help: "explicit degree list for the sweep" },
    Flag { name: "--exact-consensus", value: "", commands: "train sweep info", default: "",
        toml: "network.exact_consensus", help: "idealized exact averaging instead of gossip (ablation)" },
    Flag { name: "--schedule", value: "sync|semisync|lossy", commands: "train serve worker sweep info", default: "sync",
        toml: "network.schedule", help: "communication fabric: synchronous, bounded-staleness, or lossy gossip" },
    Flag { name: "--staleness", value: "S", commands: "train serve worker sweep info", default: "2 when semisync",
        toml: "network.staleness", help: "semisync only: neighbour reads up to S rounds stale" },
    Flag { name: "--loss-p", value: "P", commands: "train serve worker sweep info", default: "0.1 when lossy",
        toml: "network.loss_p", help: "lossy only: per-round, per-edge drop probability in [0,1)" },
    Flag { name: "--adaptive-delta", value: "MAX", commands: "train serve worker sweep info", default: "",
        toml: "network.adaptive_delta", help: "L-FGADMM adaptive consensus tolerance: loosen gossip delta up to MAX on cost plateaus" },
    Flag { name: "--adaptive-period", value: "P", commands: "train serve worker sweep info", default: "1",
        toml: "network.adaptive_period", help: "L-FGADMM communication-period doubling cap (skips whole averaging calls on plateaus)" },
    Flag { name: "--iter-staleness", value: "S", commands: "train serve worker sweep info", default: "0",
        toml: "network.iter_staleness", help: "bounded-staleness ADMM (Liang et al. 2020): updates read consensus state up to S iterations old" },
    Flag { name: "--iter-schedule", value: "iid|fixed:D|oneslow:NODE:LAG", commands: "train serve worker sweep info", default: "iid",
        toml: "network.iter_schedule", help: "how staleness ages are assigned: seeded draws, a fixed lag, or one slow node" },
    Flag { name: "--straggler-sigma", value: "F", commands: "train sweep info", default: "0",
        toml: "network.straggler_sigma", help: "per-round lognormal latency heterogeneity (0 = the paper's homogeneous cluster)" },
    Flag { name: "--straggler-seed", value: "N", commands: "train sweep info", default: "0",
        toml: "network.straggler_seed", help: "seed of the per-round, per-node straggler draw stream" },
    Flag { name: "--straggler-corr", value: "R", commands: "train sweep info", default: "0",
        toml: "network.straggler_corr", help: "AR(1) persistence of slowness in [0,1]: 0 = transient spikes, 1 = fixed multipliers" },
    Flag { name: "--chaos-crash-p", value: "P", commands: "train sweep info", default: "0",
        toml: "network.chaos_crash_p", help: "per-averaging node crash probability in [0,1) (0 = fault-free)" },
    Flag { name: "--chaos-rejoin-p", value: "P", commands: "train sweep info", default: "0",
        toml: "network.chaos_rejoin_p", help: "per-averaging rejoin probability for crashed nodes (0 = crashes are permanent)" },
    Flag { name: "--chaos-seed", value: "N", commands: "train sweep info", default: "0",
        toml: "network.chaos_seed", help: "seed of the membership churn stream (crash, rejoin and backoff draws)" },
    Flag { name: "--min-nodes", value: "Q", commands: "train sweep info", default: "1",
        toml: "network.min_nodes", help: "quorum: averaging stalls (sim-time accrues, no traffic) while fewer than Q nodes are live" },
    Flag { name: "--clock", value: "closed-form|event", commands: "train sweep info", default: "closed-form",
        toml: "network.clock", help: "simulated-seconds engine: the closed-form per-round charge, or the per-node discrete-event simulator (each node waits only for its own staleness-bounded dependencies)" },
    Flag { name: "--compress", value: "none|qN|topk:F", commands: "train serve worker sweep info", default: "none",
        toml: "network.compress", help: "gossip message compression with per-edge error feedback: N-bit stochastic uniform quantization (1<=N<=8) or magnitude top-k keeping fraction F" },
    Flag { name: "--backend", value: "native|pjrt", commands: "train info", default: "native",
        toml: "runtime.backend", help: "compute backend for the dense kernels" },
    Flag { name: "--artifacts", value: "DIR", commands: "train info", default: "artifacts",
        toml: "runtime.artifacts", help: "HLO artifact directory for the PJRT backend" },
    Flag { name: "--threads", value: "N", commands: "train sweep", default: "0 (auto)",
        toml: "runtime.threads", help: "worker threads (node fan-out first, leftovers to intra-node kernels)" },
    Flag { name: "--no-curve", value: "", commands: "train serve worker sweep", default: "",
        toml: "runtime.record_cost_curve", help: "skip per-iteration cost recording (throughput runs)" },
    Flag { name: "--verbose", value: "", commands: "train serve", default: "",
        toml: "", help: "stream every typed StepEvent to stderr" },
    Flag { name: "--csv", value: "PATH", commands: "train serve sweep", default: "",
        toml: "", help: "write the cost curve (train, serve) or sweep rows (sweep) as CSV" },
    Flag { name: "--checkpoint", value: "PATH", commands: "train", default: "",
        toml: "", help: "snapshot the full session state at every layer boundary" },
    Flag { name: "--checkpoint-every", value: "K", commands: "train", default: "",
        toml: "", help: "additionally snapshot every K ADMM iterations (needs --checkpoint)" },
    Flag { name: "--resume", value: "PATH", commands: "train", default: "",
        toml: "", help: "continue a checkpoint bit-identically (the file carries the run's configuration)" },
    Flag { name: "--max-bytes", value: "N", commands: "train", default: "",
        toml: "", help: "stop after N communicated bytes (model stays well-formed)" },
    Flag { name: "--max-sim-secs", value: "S", commands: "train", default: "",
        toml: "", help: "stop after S simulated seconds (compute + alpha-beta comm)" },
    Flag { name: "--cost-plateau", value: "F", commands: "train", default: "",
        toml: "", help: "stop growing layers once the relative cost improvement falls below F" },
    Flag { name: "--bind", value: "ADDR", commands: "serve", default: "",
        toml: "", help: "TCP address to listen on for workers (port 0 picks a free port)" },
    Flag { name: "--min-clients", value: "K", commands: "serve", default: "0 (= all M)",
        toml: "", help: "start once K distinct shards have joined; absent shards count as crashed and may rejoin later" },
    Flag { name: "--connect", value: "ADDR", commands: "worker", default: "",
        toml: "", help: "the `serve` coordinator's address" },
    Flag { name: "--shard", value: "I", commands: "worker", default: "",
        toml: "", help: "this worker's shard index in 0..M (each index joins exactly once)" },
    Flag { name: "--io-timeout", value: "SECS", commands: "serve worker", default: "none (30s handshakes)",
        toml: "", help: "read/write timeout on wire connections; 0 = block forever" },
    Flag { name: "--reconnect-max", value: "N", commands: "worker", default: "5",
        toml: "", help: "reconnect attempts after a lost connection (exponential backoff, then server catch-up)" },
    Flag { name: "--weights-out", value: "PATH", commands: "train serve", default: "",
        toml: "", help: "write the trained weight stack + output matrix (byte-diffable across transports)" },
];

/// `--config` file keys with no flag equivalent — the rest of the
/// hand-maintained key list in `config.rs`'s header comment, folded in
/// here so `docs/CLI.md` documents the whole TOML surface.
pub const TOML_ONLY: &[(&str, &str)] = &[
    ("model.hidden_extra", "hidden width is n = 2Q + hidden_extra (paper: 1000)"),
    ("admm.eps", "explicit Frobenius projection radius (default 2Q)"),
    ("network.delta", "gossip consensus tolerance per averaging call (default 1e-9)"),
    ("network.alpha", "latency model: per-round setup cost in seconds (default 1e-3)"),
    ("network.beta", "latency model: link bandwidth in bytes/second (default 1.25e8)"),
];

/// One row of the cross-knob rejection matrix: a knob, the
/// configuration it is rejected under, and the token the error message
/// names (flags a configuration does not read are errors, not no-ops).
pub struct Conflict {
    /// The offending knob (or knob combination).
    pub knob: &'static str,
    /// When it is rejected.
    pub rejected_when: &'static str,
    /// A token the error message is guaranteed to contain.
    pub names: &'static str,
}

/// The rejection matrix `docs/CLI.md` documents and `rust/tests/cli.rs`
/// exercises.
pub const CONFLICTS: &[Conflict] = &[
    Conflict { knob: "`--staleness`", rejected_when: "schedule is not `semisync`",
        names: "semisync" },
    Conflict { knob: "`--loss-p`", rejected_when: "schedule is not `lossy`",
        names: "lossy" },
    Conflict { knob: "`--schedule semisync|lossy`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--adaptive-delta`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--adaptive-delta`", rejected_when: "`--no-curve` is set (the controller steers off the cost curve)",
        names: "record_cost_curve" },
    Conflict { knob: "`--adaptive-period`", rejected_when: "`--adaptive-delta` is not set",
        names: "adaptive_delta" },
    Conflict { knob: "`--iter-staleness`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--iter-staleness`", rejected_when: "schedule is `semisync` or `lossy` (two resolutions of one relaxation)",
        names: "staleness" },
    Conflict { knob: "`--iter-staleness`", rejected_when: "S >= K (the last S iterations of a layer drain synchronously)",
        names: "admm_iterations" },
    Conflict { knob: "`--iter-staleness` + `--adaptive-period` > 1", rejected_when: "always (both skip consensus work per iteration)",
        names: "period" },
    Conflict { knob: "`--iter-schedule fixed:D|oneslow:...`", rejected_when: "`--iter-staleness` is 0, or the lag is outside `1..=S`",
        names: "iter_staleness" },
    Conflict { knob: "`--iter-schedule oneslow:NODE:LAG`", rejected_when: "NODE >= M",
        names: "nodes" },
    Conflict { knob: "`--iter-schedule`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--straggler-sigma`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--straggler-seed`", rejected_when: "`--straggler-sigma` is 0 (nothing is drawn)",
        names: "straggler_sigma" },
    Conflict { knob: "`--straggler-corr`", rejected_when: "`--straggler-sigma` is 0 (no slowness to correlate)",
        names: "straggler_sigma" },
    Conflict { knob: "`--chaos-crash-p`", rejected_when: "`--exact-consensus` is set",
        names: "exact_consensus" },
    Conflict { knob: "`--chaos-crash-p`", rejected_when: "`--iter-staleness` is set (frozen state has no staleness age)",
        names: "staleness" },
    Conflict { knob: "`--chaos-rejoin-p`", rejected_when: "`--chaos-crash-p` is 0 (nothing ever crashes)",
        names: "chaos_crash_p" },
    Conflict { knob: "`--chaos-seed`", rejected_when: "`--chaos-crash-p` is 0 (nothing is drawn)",
        names: "chaos_crash_p" },
    Conflict { knob: "`--min-nodes`", rejected_when: "`--chaos-crash-p` is 0, Q = 0, or Q > M",
        names: "min_nodes" },
    Conflict { knob: "`--clock event`", rejected_when: "`--exact-consensus` is set (exact averaging schedules no gossip rounds)",
        names: "exact_consensus" },
    Conflict { knob: "`--clock event`", rejected_when: "schedule is `lossy` (a dropped edge has no completion event)",
        names: "lossy" },
    Conflict { knob: "`--clock event`", rejected_when: "`--chaos-crash-p` is set (churn reshapes the dependency DAG mid-call)",
        names: "fault injection" },
    Conflict { knob: "`--compress`", rejected_when: "`--exact-consensus` is set (exact averaging exchanges no messages to compress)",
        names: "exact_consensus" },
    Conflict { knob: "`--compress`", rejected_when: "`--chaos-crash-p` is set (churn rebuilds the mixing plan the per-edge error-feedback accumulators are keyed on)",
        names: "fault injection" },
    Conflict { knob: "`--compress q0|q9|topk:0|topk:1.5|...`", rejected_when: "always (bits must be 1..=8, the kept fraction inside (0, 1))",
        names: "compress" },
    Conflict { knob: "`--checkpoint-every`", rejected_when: "`--checkpoint` is not set, or K = 0",
        names: "checkpoint" },
    Conflict { knob: "any training flag", rejected_when: "`--resume` is set (the checkpoint carries the configuration)",
        names: "cannot be combined" },
    Conflict { knob: "`--backend pjrt`", rejected_when: "`--resume` is set (checkpoints do not record a backend)",
        names: "native" },
    Conflict { knob: "transport flags (`--bind`, `--connect`, `--shard`, `--min-clients`, `--io-timeout`, `--reconnect-max`)", rejected_when: "`--resume` is set (a wire run cannot resume a checkpoint)",
        names: "cannot be combined" },
    Conflict { knob: "`--exact-consensus`", rejected_when: "under `serve`/`worker` (the wire run is real gossip)",
        names: "gossip consensus" },
    Conflict { knob: "`--backend pjrt`", rejected_when: "under `serve`/`worker` (bit-identical f64s need one backend everywhere)",
        names: "native" },
    Conflict { knob: "`--straggler-sigma`, `--chaos-crash-p`, `--clock event`", rejected_when: "under `serve`/`worker` (simulated cluster physics; real workers are their own stragglers and failures, and the wire advances in real time)",
        names: "simulation-only" },
];

/// Whether `key` (without the leading `--`) is a bare switch, derived
/// from the flag table (`value == ""`).
pub fn is_switch(key: &str) -> bool {
    FLAGS
        .iter()
        .any(|f| f.value.is_empty() && f.name.strip_prefix("--") == Some(key))
}

/// The usage text the binary prints — rendered from the same table as
/// [`markdown`], so help and docs cannot drift.
pub fn usage() -> String {
    let mut s = String::from("usage: dssfn <command> [--flag value ...]\n\ncommands:\n");
    for (name, purpose) in COMMANDS {
        s.push_str(&format!("  {name:<9} {purpose}\n"));
    }
    s.push_str("\nflags (docs/CLI.md has the full reference and the conflict rules):\n");
    for f in FLAGS {
        let head = if f.value.is_empty() {
            f.name.to_string()
        } else {
            format!("{} {}", f.name, f.value)
        };
        s.push_str(&format!("  {head:<42} [{}] {}\n", f.commands, f.help));
    }
    let _ = s.pop(); // callers add their own trailing newline
    s
}

/// Escape `|` for GitHub-flavored-Markdown table cells (a pipe splits
/// the cell even inside a backtick code span unless written as `\|`).
fn escape_cell(s: &str) -> String {
    s.replace('|', "\\|")
}

/// Render `docs/CLI.md` — the committed flag reference, pinned
/// byte-for-byte against this function by `rust/tests/cli.rs`.
pub fn markdown() -> String {
    let mut s = String::new();
    s.push_str("# `dssfn` CLI reference\n\n");
    s.push_str(
        "Generated from the flag table in `rust/src/clidoc.rs` — the same table\n\
         that renders the binary's usage text, so this document cannot drift\n\
         from the code. Regenerate after editing the table:\n\n\
         ```sh\n\
         cargo run --release -- cli-doc > docs/CLI.md\n\
         ```\n\n\
         `rust/tests/cli.rs` pins this file byte-for-byte against the renderer.\n\n",
    );
    s.push_str("## Commands\n\n| command | purpose |\n|---|---|\n");
    for (name, purpose) in COMMANDS {
        s.push_str(&format!("| `{name}` | {purpose} |\n"));
    }
    s.push_str(
        "\n## Flags\n\nThe *commands* column lists where a flag has effect. The *TOML key*\n\
         column is the `--config` file spelling of the same knob (— = CLI-only).\n\
         Flags a configuration does not read are **errors, not silent no-ops**\n\
         — see the rejection matrix below.\n\n",
    );
    s.push_str(
        "| flag | value | commands | default | TOML key | description |\n|---|---|---|---|---|---|\n",
    );
    for f in FLAGS {
        let value = if f.value.is_empty() {
            "switch".to_string()
        } else {
            format!("`{}`", escape_cell(f.value))
        };
        let default = if f.default.is_empty() {
            "—".to_string()
        } else {
            format!("`{}`", escape_cell(f.default))
        };
        let toml = if f.toml.is_empty() {
            "—".to_string()
        } else {
            format!("`{}`", f.toml)
        };
        s.push_str(&format!(
            "| `{}` | {} | {} | {} | {} | {} |\n",
            f.name,
            value,
            f.commands,
            default,
            toml,
            escape_cell(f.help)
        ));
    }
    s.push_str(
        "\n### TOML-only keys\n\nA few `--config` file keys have no flag equivalent:\n\n",
    );
    s.push_str("| TOML key | purpose |\n|---|---|\n");
    for (key, purpose) in TOML_ONLY {
        s.push_str(&format!("| `{key}` | {purpose} |\n"));
    }
    s.push_str(
        "\n## Cross-knob rejection matrix\n\nEvery row is enforced by `ExperimentConfig::comm_config()` (the one\n\
         validation path `train`, `sweep` and `info` share — `info` rejects\n\
         exactly what `train` rejects) and exercised by `rust/tests/cli.rs`.\n\n",
    );
    s.push_str("| knob | rejected when | the error names |\n|---|---|---|\n");
    for c in CONFLICTS {
        s.push_str(&format!(
            "| {} | {} | `{}` |\n",
            escape_cell(c.knob),
            escape_cell(c.rejected_when),
            escape_cell(c.names)
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn switches_are_derived_from_the_table() {
        assert!(is_switch("exact-consensus"));
        assert!(is_switch("no-curve"));
        assert!(is_switch("verbose"));
        assert!(!is_switch("schedule"));
        assert!(!is_switch("dataset"));
        assert!(!is_switch("bogus"));
    }

    #[test]
    fn usage_and_markdown_cover_every_flag_and_command() {
        let usage = usage();
        let md = markdown();
        for f in FLAGS {
            assert!(usage.contains(f.name), "usage missing {}", f.name);
            assert!(md.contains(f.name), "markdown missing {}", f.name);
        }
        for (name, _) in COMMANDS {
            assert!(usage.contains(name), "usage missing command {name}");
            assert!(md.contains(name), "markdown missing command {name}");
        }
        // The rejection matrix is rendered in full.
        for c in CONFLICTS {
            assert!(md.contains(c.names), "matrix missing {}", c.names);
        }
        // Every TOML key (from flags and the TOML-only table) is rendered.
        for f in FLAGS.iter().filter(|f| !f.toml.is_empty()) {
            assert!(md.contains(f.toml), "markdown missing TOML key {}", f.toml);
        }
        for (key, _) in TOML_ONLY {
            assert!(md.contains(key), "markdown missing TOML-only key {key}");
        }
    }

    #[test]
    fn flag_table_is_well_formed() {
        for f in FLAGS {
            assert!(f.name.starts_with("--"), "{} lacks --", f.name);
            assert!(!f.help.is_empty());
            assert!(!f.commands.is_empty());
            // Commands must come from the command table.
            for c in f.commands.split(' ') {
                assert!(
                    COMMANDS.iter().any(|(n, _)| *n == c),
                    "{}: unknown command '{c}'",
                    f.name
                );
            }
            // TOML keys are `section.key` under a known section.
            if !f.toml.is_empty() {
                let section = f.toml.split('.').next().unwrap();
                assert!(
                    ["experiment", "model", "admm", "network", "runtime"].contains(&section),
                    "{}: unknown TOML section '{section}'",
                    f.name
                );
            }
        }
        // TOML-only keys must not shadow a flag's key.
        for (key, _) in TOML_ONLY {
            assert!(
                FLAGS.iter().all(|f| f.toml != *key),
                "TOML-only key {key} duplicates a flag's key"
            );
        }
        // No duplicate flag names.
        for (i, f) in FLAGS.iter().enumerate() {
            assert!(
                FLAGS.iter().skip(i + 1).all(|g| g.name != f.name),
                "duplicate flag {}",
                f.name
            );
        }
    }
}
