//! Native `f64` compute backend — the bit-stable oracle the PJRT path is
//! verified against, and the default for the centralized baseline.

use super::ComputeBackend;
use crate::admm::{LayerLocalSolver, LocalSolve};
use crate::linalg::Matrix;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Pure-Rust backend over the crate's own linalg.
///
/// Carries the coordinator's intra-node thread hint (an atomic so the
/// shared `&self` backend handle can be re-tuned between training runs):
/// `prepare_layer` feeds it to the row-banded Gram build, which is
/// bit-identical to the sequential build for every thread count.
#[derive(Debug, Default)]
pub struct NativeBackend {
    /// Threads a single kernel call may use; `0` means 1.
    intra_threads: AtomicUsize,
}

impl Clone for NativeBackend {
    fn clone(&self) -> Self {
        Self {
            intra_threads: AtomicUsize::new(self.intra_threads.load(Ordering::Relaxed)),
        }
    }
}

impl NativeBackend {
    /// Create a native backend.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a native backend with an intra-kernel thread budget.
    pub fn with_intra_threads(threads: usize) -> Self {
        let b = Self::default();
        b.intra_threads.store(threads, Ordering::Relaxed);
        b
    }

    fn intra(&self) -> usize {
        self.intra_threads.load(Ordering::Relaxed).max(1)
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn set_intra_threads(&self, threads: usize) {
        self.intra_threads.store(threads, Ordering::Relaxed);
    }

    fn layer_forward(&self, w: &Matrix, y: &Matrix) -> Result<Matrix> {
        let mut out = w.matmul(y)?;
        out.relu_inplace();
        Ok(out)
    }

    fn prepare_layer(&self, y: &Matrix, t: &Matrix, mu: f64) -> Result<Box<dyn LocalSolve>> {
        Ok(Box::new(LayerLocalSolver::with_threads(
            y,
            t,
            mu,
            self.intra(),
        )?))
    }

    fn output_scores(&self, o: &Matrix, y: &Matrix) -> Result<Matrix> {
        o.matmul(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    #[test]
    fn forward_is_relu_of_matmul() {
        let b = NativeBackend::new();
        let w = Matrix::from_rows(&[vec![1.0, -1.0], vec![-2.0, 0.5]]).unwrap();
        let y = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]).unwrap();
        let out = b.layer_forward(&w, &y).unwrap();
        // W·Y = [[1,-2],[-2,1]] → relu
        assert_eq!(out.get(0, 0), 1.0);
        assert_eq!(out.get(0, 1), 0.0);
        assert_eq!(out.get(1, 0), 0.0);
        assert_eq!(out.get(1, 1), 1.0);
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn intra_thread_hint_never_changes_results() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(3);
        let y = Matrix::from_fn(70, 50, |_, _| rng.uniform(-1.0, 1.0));
        let t = Matrix::from_fn(3, 50, |_, _| rng.uniform(-1.0, 1.0));
        let b1 = NativeBackend::new();
        let b4 = NativeBackend::with_intra_threads(4);
        let s1 = b1.prepare_layer(&y, &t, 1.0).unwrap();
        let s4 = b4.prepare_layer(&y, &t, 1.0).unwrap();
        let z = Matrix::from_fn(3, 70, |r, c| ((r + 2 * c) as f64).sin());
        let o1 = s1.o_update(&z, &z).unwrap();
        let o4 = s4.o_update(&z, &z).unwrap();
        assert_eq!(o1.max_abs_diff(&o4), 0.0);
        // Re-tuning through the trait hint is equivalent.
        let bh = NativeBackend::new();
        bh.set_intra_threads(4);
        let sh = bh.prepare_layer(&y, &t, 1.0).unwrap();
        assert_eq!(sh.o_update(&z, &z).unwrap().max_abs_diff(&o1), 0.0);
    }

    #[test]
    fn prepare_layer_gives_working_solver() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let y = Matrix::from_fn(4, 20, |_, _| rng.uniform(-1.0, 1.0));
        let t = Matrix::from_fn(2, 20, |_, _| rng.uniform(-1.0, 1.0));
        let b = NativeBackend::new();
        let solver = b.prepare_layer(&y, &t, 1.0).unwrap();
        let z = Matrix::zeros(2, 4);
        let o = solver.o_update(&z, &z).unwrap();
        assert_eq!(o.shape(), (2, 4));
        let c = solver.cost(&o).unwrap();
        assert!(c >= 0.0);
        let scores = b.output_scores(&o, &y).unwrap();
        assert_eq!(scores.shape(), (2, 20));
    }
}
