//! Native `f64` compute backend — the bit-stable oracle the PJRT path is
//! verified against, and the default for the centralized baseline.

use super::ComputeBackend;
use crate::admm::{LayerLocalSolver, LocalSolve};
use crate::linalg::Matrix;
use crate::Result;

/// Pure-Rust backend over the crate's own linalg.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl NativeBackend {
    /// Create a native backend.
    pub fn new() -> Self {
        Self
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn layer_forward(&self, w: &Matrix, y: &Matrix) -> Result<Matrix> {
        let mut out = w.matmul(y)?;
        out.relu_inplace();
        Ok(out)
    }

    fn prepare_layer(&self, y: &Matrix, t: &Matrix, mu: f64) -> Result<Box<dyn LocalSolve>> {
        Ok(Box::new(LayerLocalSolver::new(y, t, mu)?))
    }

    fn output_scores(&self, o: &Matrix, y: &Matrix) -> Result<Matrix> {
        o.matmul(y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    #[test]
    fn forward_is_relu_of_matmul() {
        let b = NativeBackend::new();
        let w = Matrix::from_rows(&[vec![1.0, -1.0], vec![-2.0, 0.5]]).unwrap();
        let y = Matrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]).unwrap();
        let out = b.layer_forward(&w, &y).unwrap();
        // W·Y = [[1,-2],[-2,1]] → relu
        assert_eq!(out.get(0, 0), 1.0);
        assert_eq!(out.get(0, 1), 0.0);
        assert_eq!(out.get(1, 0), 0.0);
        assert_eq!(out.get(1, 1), 1.0);
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn prepare_layer_gives_working_solver() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(1);
        let y = Matrix::from_fn(4, 20, |_, _| rng.uniform(-1.0, 1.0));
        let t = Matrix::from_fn(2, 20, |_, _| rng.uniform(-1.0, 1.0));
        let b = NativeBackend::new();
        let solver = b.prepare_layer(&y, &t, 1.0).unwrap();
        let z = Matrix::zeros(2, 4);
        let o = solver.o_update(&z, &z).unwrap();
        assert_eq!(o.shape(), (2, 4));
        let c = solver.cost(&o).unwrap();
        assert!(c >= 0.0);
        let scores = b.output_scores(&o, &y).unwrap();
        assert_eq!(scores.shape(), (2, 20));
    }
}
