//! PJRT compute backend: executes the AOT-compiled HLO artifacts.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and therefore not `Send`;
//! worker threads cannot share it. The backend instead runs a dedicated
//! **service thread** that owns the client and the compiled executables,
//! and exposes a cloneable, `Send + Sync` handle that forwards kernel
//! requests over an mpsc channel. CPU PJRT parallelizes internally, so a
//! single submission thread is not the bottleneck (verified in
//! `EXPERIMENTS.md §Perf`).
//!
//! Shape discipline: artifacts are compiled for a fixed padded per-shard
//! width `J`. Inputs with fewer columns are zero-padded — zero sample
//! columns are exactly neutral through the whole dSSFN pipeline (they add
//! nothing to `Y Yᵀ` or `T Yᵀ`, and `g(W·0) = 0` keeps them zero through
//! every layer).

use super::artifact::{ArtifactManifest, ManifestEntry};
use super::ComputeBackend;
use crate::admm::LocalSolve;
use crate::linalg::Matrix;
use crate::{Error, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Kernel identifiers matching the artifact entry set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kernel {
    FirstForward,
    Forward,
    GramP,
    GramN,
    InvP,
    InvN,
    OUpdateP,
    OUpdateN,
    Output,
}

impl Kernel {
    fn entry(self) -> &'static str {
        match self {
            Kernel::FirstForward => "first_forward",
            Kernel::Forward => "forward",
            Kernel::GramP => "gram_p",
            Kernel::GramN => "gram_n",
            Kernel::InvP => "inv_p",
            Kernel::InvN => "inv_n",
            Kernel::OUpdateP => "o_update_p",
            Kernel::OUpdateN => "o_update_n",
            Kernel::Output => "output",
        }
    }

    const ALL: [Kernel; 9] = [
        Kernel::FirstForward,
        Kernel::Forward,
        Kernel::GramP,
        Kernel::GramN,
        Kernel::InvP,
        Kernel::InvN,
        Kernel::OUpdateP,
        Kernel::OUpdateN,
        Kernel::Output,
    ];

    fn index(self) -> usize {
        Kernel::ALL.iter().position(|k| *k == self).unwrap()
    }
}

/// Requests to the service thread.
enum Request {
    /// Run a kernel with host operands (uploaded per call).
    Kernel {
        kernel: Kernel,
        operands: Vec<Matrix>,
        scalar: Option<f64>,
        reply: mpsc::Sender<Result<Vec<Matrix>>>,
    },
    /// Upload a layer's loop-invariant O-update operands (`T·Yᵀ`, `G⁻¹`)
    /// to device buffers once; returns a handle for [`Request::OUpdate`].
    /// §Perf: avoids re-uploading `n² + Q·n` f32 words on every one of
    /// the `K` ADMM iterations.
    LoadSolver {
        kernel: Kernel,
        tyt: Matrix,
        ginv: Matrix,
        reply: mpsc::Sender<Result<u64>>,
    },
    /// Per-iteration O-update against cached buffers.
    OUpdate {
        id: u64,
        z: Matrix,
        lam: Matrix,
        mu_inv: f64,
        reply: mpsc::Sender<Result<Vec<Matrix>>>,
    },
    /// Release a cached solver's buffers.
    DropSolver { id: u64 },
}

/// Handle to the PJRT service thread. Cloneable, `Send + Sync`.
#[derive(Clone)]
pub struct PjrtBackend {
    inner: Arc<Inner>,
    cfg: ManifestEntry,
}

struct Inner {
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Drop for Inner {
    fn drop(&mut self) {
        // Closing the channel stops the service loop.
        self.tx.lock().map(|mut g| g.take()).ok();
        if let Ok(mut g) = self.join.lock() {
            if let Some(h) = g.take() {
                h.join().ok();
            }
        }
    }
}

impl PjrtBackend {
    /// Start a backend for one artifact configuration. Compiles all nine
    /// entrypoints up front; fails fast if any artifact is missing or
    /// rejected by the PJRT compiler.
    pub fn start(manifest: &ArtifactManifest, config: &str) -> Result<Self> {
        let cfg = manifest.config(config)?.clone();
        cfg.verify_files(manifest.root())?;
        let paths: Vec<std::path::PathBuf> = Kernel::ALL
            .iter()
            .map(|k| cfg.entry_path(manifest.root(), k.entry()))
            .collect();

        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || service_main(paths, rx, ready_tx))
            .map_err(|e| Error::Runtime(format!("cannot spawn pjrt thread: {e}")))?;
        // Wait for compilation handshake.
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                join.join().ok();
                return Err(e);
            }
            Err(_) => {
                join.join().ok();
                return Err(Error::Runtime("pjrt service died during startup".into()));
            }
        }
        Ok(Self {
            inner: Arc::new(Inner {
                tx: Mutex::new(Some(tx)),
                join: Mutex::new(Some(join)),
            }),
            cfg,
        })
    }

    /// The shape configuration this backend serves.
    pub fn config(&self) -> &ManifestEntry {
        &self.cfg
    }

    fn send(&self, req: Request) -> Result<()> {
        let guard = self
            .inner
            .tx
            .lock()
            .map_err(|_| Error::Runtime("pjrt handle poisoned".into()))?;
        let tx = guard
            .as_ref()
            .ok_or_else(|| Error::Runtime("pjrt service stopped".into()))?;
        tx.send(req)
            .map_err(|_| Error::Runtime("pjrt service channel closed".into()))
    }

    fn call(&self, kernel: Kernel, operands: Vec<Matrix>, scalar: Option<f64>) -> Result<Vec<Matrix>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::Kernel {
            kernel,
            operands,
            scalar,
            reply,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("pjrt service dropped request".into()))?
    }

    fn load_solver(&self, kernel: Kernel, tyt: Matrix, ginv: Matrix) -> Result<u64> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::LoadSolver {
            kernel,
            tyt,
            ginv,
            reply,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("pjrt service dropped request".into()))?
    }

    fn o_update_cached(&self, id: u64, z: &Matrix, lam: &Matrix, mu_inv: f64) -> Result<Vec<Matrix>> {
        let (reply, rx) = mpsc::channel();
        self.send(Request::OUpdate {
            id,
            z: z.clone(),
            lam: lam.clone(),
            mu_inv,
            reply,
        })?;
        rx.recv()
            .map_err(|_| Error::Runtime("pjrt service dropped request".into()))?
    }

    /// Zero-pad `m` to `cols` columns (no-op if already that wide).
    fn pad_cols(m: &Matrix, cols: usize) -> Result<Matrix> {
        if m.cols() == cols {
            return Ok(m.clone());
        }
        if m.cols() > cols {
            return Err(Error::Runtime(format!(
                "shard has {} samples but artifact J={cols}; regenerate artifacts",
                m.cols()
            )));
        }
        let mut out = Matrix::zeros(m.rows(), cols);
        for r in 0..m.rows() {
            out.row_mut(r)[..m.cols()].copy_from_slice(m.row(r));
        }
        Ok(out)
    }

    fn feature_kernelset(&self, dim: usize) -> Result<(Kernel, Kernel, Kernel)> {
        if dim == self.cfg.n {
            Ok((Kernel::GramN, Kernel::InvN, Kernel::OUpdateN))
        } else if dim == self.cfg.p {
            Ok((Kernel::GramP, Kernel::InvP, Kernel::OUpdateP))
        } else {
            Err(Error::Runtime(format!(
                "feature dim {dim} matches neither p={} nor n={} of config '{}'",
                self.cfg.p, self.cfg.n, self.cfg.name
            )))
        }
    }
}

impl ComputeBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn layer_forward(&self, w: &Matrix, y: &Matrix) -> Result<Matrix> {
        let kernel = if y.rows() == self.cfg.p && w.cols() == self.cfg.p && self.cfg.p != self.cfg.n
        {
            Kernel::FirstForward
        } else {
            Kernel::Forward
        };
        let j_orig = y.cols();
        let y_pad = Self::pad_cols(y, self.cfg.j)?;
        let mut out = self
            .call(kernel, vec![w.clone(), y_pad], None)?
            .pop()
            .ok_or_else(|| Error::Runtime("forward returned no output".into()))?;
        if j_orig != self.cfg.j {
            out = out.col_block(0, j_orig)?;
        }
        Ok(out)
    }

    fn prepare_layer(&self, y: &Matrix, t: &Matrix, mu: f64) -> Result<Box<dyn LocalSolve>> {
        if mu <= 0.0 {
            return Err(Error::Config(format!("mu must be positive, got {mu}")));
        }
        let (gram_k, inv_k, upd_k) = self.feature_kernelset(y.rows())?;
        let mu_inv = 1.0 / mu;
        let y_pad = Self::pad_cols(y, self.cfg.j)?;
        let t_pad = Self::pad_cols(t, self.cfg.j)?;
        let mut grams = self.call(gram_k, vec![y_pad, t_pad], Some(mu_inv))?;
        if grams.len() != 2 {
            return Err(Error::Runtime(format!(
                "gram kernel returned {} outputs, expected 2",
                grams.len()
            )));
        }
        let tyt = grams.pop().unwrap();
        let g = grams.pop().unwrap();
        let ginv = self
            .call(inv_k, vec![g.clone()], None)?
            .pop()
            .ok_or_else(|| Error::Runtime("inverse returned no output".into()))?;
        // gram0 = G − μ⁻¹I, kept in f64 for exact cost accounting.
        let mut gram0 = g;
        gram0.add_diag(-mu_inv)?;
        // Park the loop-invariant operands on the device once.
        let id = self.load_solver(upd_k, tyt.clone(), ginv)?;
        Ok(Box::new(PjrtLayerSolver {
            backend: self.clone(),
            solver_id: id,
            tyt,
            gram0,
            t_norm_sq: t.frobenius_norm_sq(),
            mu_inv,
        }))
    }

    fn output_scores(&self, o: &Matrix, y: &Matrix) -> Result<Matrix> {
        let j_orig = y.cols();
        let y_pad = Self::pad_cols(y, self.cfg.j)?;
        let mut out = self
            .call(Kernel::Output, vec![o.clone(), y_pad], None)?
            .pop()
            .ok_or_else(|| Error::Runtime("output returned no output".into()))?;
        if j_orig != self.cfg.j {
            out = out.col_block(0, j_orig)?;
        }
        Ok(out)
    }
}

/// Node-local ADMM solver whose O-update runs on the PJRT artifact
/// against device-cached loop-invariant operands.
struct PjrtLayerSolver {
    backend: PjrtBackend,
    solver_id: u64,
    tyt: Matrix,
    gram0: Matrix,
    t_norm_sq: f64,
    mu_inv: f64,
}

impl Drop for PjrtLayerSolver {
    fn drop(&mut self) {
        self.backend
            .send(Request::DropSolver { id: self.solver_id })
            .ok();
    }
}

impl LocalSolve for PjrtLayerSolver {
    fn o_update(&self, z: &Matrix, lambda: &Matrix) -> Result<Matrix> {
        self.backend
            .o_update_cached(self.solver_id, z, lambda, self.mu_inv)?
            .pop()
            .ok_or_else(|| Error::Runtime("o_update returned no output".into()))
    }

    fn cost(&self, o: &Matrix) -> Result<f64> {
        // ‖T‖² − 2⟨O, TYᵀ⟩ + ⟨O·(YYᵀ), O⟩ from the cached Grams.
        let og = o.matmul(&self.gram0)?;
        let mut quad = 0.0;
        let mut cross = 0.0;
        for (a, (b, c)) in o
            .as_slice()
            .iter()
            .zip(og.as_slice().iter().zip(self.tyt.as_slice()))
        {
            quad += a * b;
            cross += a * c;
        }
        Ok((self.t_norm_sq - 2.0 * cross + quad).max(0.0))
    }
}

// ---------------------------------------------------------------------
// Service thread
// ---------------------------------------------------------------------

fn service_main(
    paths: Vec<std::path::PathBuf>,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<()>>,
) {
    let setup = || -> Result<(xla::PjRtClient, Vec<xla::PjRtLoadedExecutable>)> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PjRtClient::cpu: {e}")))?;
        let mut execs = Vec::with_capacity(paths.len());
        for p in &paths {
            let proto = xla::HloModuleProto::from_text_file(
                p.to_str()
                    .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
            )
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", p.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| Error::Runtime(format!("compile {}: {e}", p.display())))?;
            execs.push(exe);
        }
        Ok((client, execs))
    };
    let (client, execs) = match setup() {
        Ok(v) => {
            ready.send(Ok(())).ok();
            v
        }
        Err(e) => {
            ready.send(Err(e)).ok();
            return;
        }
    };
    // Device-cached solver operands: id -> (kernel, tyt buffer, ginv buffer).
    let mut solvers: std::collections::HashMap<u64, (Kernel, xla::PjRtBuffer, xla::PjRtBuffer)> =
        std::collections::HashMap::new();
    let mut next_id = 0u64;

    while let Ok(req) = rx.recv() {
        match req {
            Request::Kernel {
                kernel,
                operands,
                scalar,
                reply,
            } => {
                let result = run_kernel(&execs[kernel.index()], &operands, scalar);
                reply.send(result).ok();
            }
            Request::LoadSolver {
                kernel,
                tyt,
                ginv,
                reply,
            } => {
                let result = (|| -> Result<u64> {
                    let tyt_b = upload(&client, &tyt)?;
                    let ginv_b = upload(&client, &ginv)?;
                    let id = next_id;
                    next_id += 1;
                    solvers.insert(id, (kernel, tyt_b, ginv_b));
                    Ok(id)
                })();
                reply.send(result).ok();
            }
            Request::OUpdate {
                id,
                z,
                lam,
                mu_inv,
                reply,
            } => {
                let result = (|| -> Result<Vec<Matrix>> {
                    let (kernel, tyt_b, ginv_b) = solvers
                        .get(&id)
                        .ok_or_else(|| Error::Runtime(format!("no cached solver {id}")))?;
                    let z_b = upload(&client, &z)?;
                    let lam_b = upload(&client, &lam)?;
                    let mu_b = client
                        .buffer_from_host_buffer::<f32>(&[mu_inv as f32], &[], None)
                        .map_err(|e| Error::Runtime(format!("scalar upload: {e}")))?;
                    // Parameter order matches the o_update artifact ABI:
                    // (tyt, z, lam, ginv, mu_inv).
                    let buffers = execs[kernel.index()]
                        .execute_b::<&xla::PjRtBuffer>(&[tyt_b, &z_b, &lam_b, ginv_b, &mu_b])
                        .map_err(|e| Error::Runtime(format!("execute_b: {e}")))?;
                    read_outputs(&buffers)
                })();
                reply.send(result).ok();
            }
            Request::DropSolver { id } => {
                solvers.remove(&id);
            }
        }
    }
    let _client = client; // keep alive for the executables' lifetime
}

/// Upload a matrix as an f32 device buffer.
fn upload(client: &xla::PjRtClient, m: &Matrix) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer::<f32>(&m.to_f32_vec(), &[m.rows(), m.cols()], None)
        .map_err(|e| Error::Runtime(format!("buffer upload: {e}")))
}

fn run_kernel(
    exe: &xla::PjRtLoadedExecutable,
    operands: &[Matrix],
    scalar: Option<f64>,
) -> Result<Vec<Matrix>> {
    let mut literals: Vec<xla::Literal> = Vec::with_capacity(operands.len() + 1);
    for m in operands {
        let lit = xla::Literal::vec1(&m.to_f32_vec())
            .reshape(&[m.rows() as i64, m.cols() as i64])
            .map_err(|e| Error::Runtime(format!("literal reshape: {e}")))?;
        literals.push(lit);
    }
    if let Some(s) = scalar {
        literals.push(xla::Literal::scalar(s as f32));
    }
    let buffers = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::Runtime(format!("execute: {e}")))?;
    read_outputs(&buffers)
}

/// Read the tupled outputs of an execution back into host matrices
/// (aot.py lowers with `return_tuple=True`).
fn read_outputs(buffers: &[Vec<xla::PjRtBuffer>]) -> Result<Vec<Matrix>> {
    let out = buffers[0][0]
        .to_literal_sync()
        .map_err(|e| Error::Runtime(format!("to_literal: {e}")))?;
    let parts = out
        .to_tuple()
        .map_err(|e| Error::Runtime(format!("untuple: {e}")))?;
    let mut results = Vec::with_capacity(parts.len());
    for lit in parts {
        let shape = lit
            .array_shape()
            .map_err(|e| Error::Runtime(format!("shape: {e}")))?;
        let dims = shape.dims();
        let (rows, cols) = match dims.len() {
            2 => (dims[0] as usize, dims[1] as usize),
            1 => (1usize, dims[0] as usize),
            0 => (1usize, 1usize),
            _ => {
                return Err(Error::Runtime(format!(
                    "unexpected output rank {}",
                    dims.len()
                )))
            }
        };
        let v: Vec<f32> = lit
            .to_vec()
            .map_err(|e| Error::Runtime(format!("to_vec: {e}")))?;
        results.push(Matrix::from_f32_slice(rows, cols, &v)?);
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_cols_behaviour() {
        let m = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let p = PjrtBackend::pad_cols(&m, 4).unwrap();
        assert_eq!(p.shape(), (2, 4));
        assert_eq!(p.get(0, 1), 2.0);
        assert_eq!(p.get(0, 3), 0.0);
        let same = PjrtBackend::pad_cols(&m, 2).unwrap();
        assert_eq!(same, m);
        assert!(PjrtBackend::pad_cols(&m, 1).is_err());
    }

    #[test]
    fn missing_artifacts_fail_fast() {
        let manifest = ArtifactManifest::parse(
            "config ghost p=2 q=2 n=6 j=4\n",
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        assert!(PjrtBackend::start(&manifest, "ghost").is_err());
        assert!(PjrtBackend::start(&manifest, "missing").is_err());
    }

    // End-to-end PJRT execution tests live in rust/tests/pjrt_parity.rs
    // and run only when `make artifacts` has produced the HLO files.
}
