//! AOT artifact manifest.
//!
//! `make artifacts` (python/compile/aot.py) lowers the L2 JAX graph —
//! including the L1 Pallas kernels — to one HLO-text file per entrypoint
//! and shape configuration, and writes a line-oriented manifest:
//!
//! ```text
//! # dssfn artifact manifest v1
//! config quickstart p=12 q=4 n=48 j=10
//! config mnist-small p=64 q=10 n=220 j=100
//! ```
//!
//! Entry files live at `artifacts/<config>/<entry>.hlo.txt` with a fixed
//! entry set (see [`ENTRIES`]). HLO is shape-specialized, so each config
//! carries its padded per-shard sample count `j`; the PJRT backend
//! zero-pads smaller shards up to `j` (zero columns are exactly neutral:
//! they contribute nothing to Grams and stay zero through ReLU layers).

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The fixed artifact entry names per configuration.
pub const ENTRIES: &[&str] = &[
    "first_forward", // relu(W[n,p] @ X[p,j])
    "forward",       // relu(W[n,n] @ Y[n,j])
    "gram_p",        // (X Xᵀ + μ⁻¹ I [p,p], T Xᵀ [q,p])
    "gram_n",        // (Y Yᵀ + μ⁻¹ I [n,n], T Yᵀ [q,n])
    "inv_p",         // G⁻¹ [p,p]
    "inv_n",         // G⁻¹ [n,n]
    "o_update_p",    // (TYᵀ + μ⁻¹(Z−Λ)) @ G⁻¹, feature dim p
    "o_update_n",    // (TYᵀ + μ⁻¹(Z−Λ)) @ G⁻¹, feature dim n
    "output",        // O[q,n] @ Y[n,j]
];

/// One shape configuration in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Config name (usually the dataset key).
    pub name: String,
    /// Input dimension `P`.
    pub p: usize,
    /// Classes `Q`.
    pub q: usize,
    /// Hidden width `n`.
    pub n: usize,
    /// Padded per-shard sample count `J`.
    pub j: usize,
}

impl ManifestEntry {
    /// Path of an entry's HLO file below the artifact root.
    pub fn entry_path(&self, root: &Path, entry: &str) -> PathBuf {
        root.join(&self.name).join(format!("{entry}.hlo.txt"))
    }

    /// Check all expected HLO files exist.
    pub fn verify_files(&self, root: &Path) -> Result<()> {
        for e in ENTRIES {
            let p = self.entry_path(root, e);
            if !p.is_file() {
                return Err(Error::Runtime(format!(
                    "missing artifact {} (run `make artifacts`)",
                    p.display()
                )));
            }
        }
        Ok(())
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    root: PathBuf,
    configs: BTreeMap<String, ManifestEntry>,
}

impl ArtifactManifest {
    /// Load `<root>/manifest.txt`.
    pub fn load(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let path = root.join("manifest.txt");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, root)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, root: PathBuf) -> Result<Self> {
        let mut configs = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let kw = parts.next().unwrap_or("");
            if kw != "config" {
                return Err(Error::Runtime(format!(
                    "manifest line {}: expected 'config', got '{kw}'",
                    lineno + 1
                )));
            }
            let name = parts
                .next()
                .ok_or_else(|| Error::Runtime(format!("manifest line {}: missing name", lineno + 1)))?
                .to_string();
            let mut fields: BTreeMap<&str, usize> = BTreeMap::new();
            for kv in parts {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    Error::Runtime(format!("manifest line {}: bad field '{kv}'", lineno + 1))
                })?;
                let v: usize = v.parse().map_err(|_| {
                    Error::Runtime(format!("manifest line {}: bad number '{v}'", lineno + 1))
                })?;
                fields.insert(k, v);
            }
            let need = |k: &str| -> Result<usize> {
                fields.get(k).copied().ok_or_else(|| {
                    Error::Runtime(format!("manifest config '{name}': missing field '{k}'"))
                })
            };
            let entry = ManifestEntry {
                p: need("p")?,
                q: need("q")?,
                n: need("n")?,
                j: need("j")?,
                name: name.clone(),
            };
            configs.insert(name, entry);
        }
        Ok(Self { root, configs })
    }

    /// Artifact root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Look up a configuration by name.
    pub fn config(&self, name: &str) -> Result<&ManifestEntry> {
        self.configs.get(name).ok_or_else(|| {
            Error::Runtime(format!(
                "no artifact config '{name}' in {} (have: {:?})",
                self.root.display(),
                self.configs.keys().collect::<Vec<_>>()
            ))
        })
    }

    /// All config names.
    pub fn config_names(&self) -> Vec<&str> {
        self.configs.keys().map(|s| s.as_str()).collect()
    }

    /// Number of configs.
    pub fn len(&self) -> usize {
        self.configs.len()
    }

    /// Whether the manifest is empty.
    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# dssfn artifact manifest v1
config quickstart p=12 q=4 n=48 j=10

config mnist-small p=64 q=10 n=220 j=100
";

    #[test]
    fn parses_configs() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.len(), 2);
        let c = m.config("quickstart").unwrap();
        assert_eq!((c.p, c.q, c.n, c.j), (12, 4, 48, 10));
        assert_eq!(m.config_names(), vec!["mnist-small", "quickstart"]);
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn entry_paths_follow_convention() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let c = m.config("quickstart").unwrap();
        assert_eq!(
            c.entry_path(m.root(), "gram_n"),
            PathBuf::from("/tmp/a/quickstart/gram_n.hlo.txt")
        );
        // verify_files fails when files are absent.
        assert!(c.verify_files(m.root()).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(ArtifactManifest::parse("bogus line", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("config x p=1 q=2 n=3", PathBuf::new()).is_err()); // missing j
        assert!(ArtifactManifest::parse("config x p=z q=2 n=3 j=4", PathBuf::new()).is_err());
        assert!(ArtifactManifest::parse("config x p 12", PathBuf::new()).is_err());
    }

    #[test]
    fn empty_manifest_ok() {
        let m = ArtifactManifest::parse("# nothing\n", PathBuf::new()).unwrap();
        assert!(m.is_empty());
    }
}
