//! Offline stand-in for the PJRT backend (compiled without the `pjrt`
//! feature).
//!
//! The real backend (`pjrt.rs`) drives the AOT-compiled HLO artifacts
//! through the `xla` crate's PJRT CPU client — an external dependency the
//! offline build image cannot vendor. This stub keeps the same public
//! surface so every call site compiles unchanged: [`PjrtBackend::start`]
//! validates the manifest exactly like the real backend would, then
//! reports the backend as unavailable. Callers already treat a failed
//! `start` as "skip the PJRT path" (see `tests/pjrt_parity.rs` and
//! `benches/microbench.rs`), so default builds stay green.

use super::artifact::{ArtifactManifest, ManifestEntry};
use super::ComputeBackend;
use crate::admm::LocalSolve;
use crate::linalg::Matrix;
use crate::{Error, Result};

/// Stub handle with the same API as the real PJRT backend.
#[derive(Debug, Clone)]
pub struct PjrtBackend {
    cfg: ManifestEntry,
}

const UNAVAILABLE: &str =
    "PJRT backend unavailable: dssfn was built without the `pjrt` feature \
     (the `xla` crate is not vendored in this image); use the native backend";

impl PjrtBackend {
    /// Validate the manifest/config pair, then fail with a clear
    /// "feature not enabled" error.
    pub fn start(manifest: &ArtifactManifest, config: &str) -> Result<Self> {
        let cfg = manifest.config(config)?.clone();
        cfg.verify_files(manifest.root())?;
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    /// The shape configuration this backend serves.
    pub fn config(&self) -> &ManifestEntry {
        &self.cfg
    }
}

impl ComputeBackend for PjrtBackend {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn layer_forward(&self, _w: &Matrix, _y: &Matrix) -> Result<Matrix> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    fn prepare_layer(&self, _y: &Matrix, _t: &Matrix, _mu: f64) -> Result<Box<dyn LocalSolve>> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }

    fn output_scores(&self, _o: &Matrix, _y: &Matrix) -> Result<Matrix> {
        Err(Error::Runtime(UNAVAILABLE.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn start_fails_fast_without_feature() {
        let manifest = ArtifactManifest::parse(
            "config ghost p=2 q=2 n=6 j=4\n",
            std::path::PathBuf::from("/nonexistent"),
        )
        .unwrap();
        // Unknown config is still a manifest error, not a feature error.
        assert!(PjrtBackend::start(&manifest, "missing").is_err());
        // Known config fails on artifact files (or, were they present, on
        // the disabled feature) — either way `start` errors and callers
        // skip the PJRT path.
        assert!(PjrtBackend::start(&manifest, "ghost").is_err());
    }
}
