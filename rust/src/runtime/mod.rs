//! Compute backends: native `f64` reference and the PJRT artifact path.
//!
//! The coordinator is generic over a [`ComputeBackend`] that supplies the
//! four dense kernels of the dSSFN hot path:
//!
//! 1. `layer_forward` — `g(W·Y)` (L1 Pallas kernel `matmul_relu`),
//! 2. `prepare_layer` — Grams `G = Y Yᵀ + μ⁻¹I`, `T Yᵀ` and the hoisted
//!    `G⁻¹` (L1 kernel `gram`, L2 `gram_inverse`),
//! 3. the per-iteration O-update inside the returned [`LocalSolve`]
//!    (L1 kernel `admm_o_update`),
//! 4. `output_scores` — `O·Y` for prediction.
//!
//! [`NativeBackend`] implements all of it with the crate's own `f64`
//! linalg and doubles as the bit-stable oracle; [`PjrtBackend`] executes
//! the AOT-compiled HLO artifacts produced by `make artifacts` via the
//! PJRT CPU client (`xla` crate). Python never runs at training time.

mod artifact;
mod native;
mod pjrt;

pub use artifact::{ArtifactManifest, ManifestEntry};
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use crate::admm::LocalSolve;
use crate::linalg::Matrix;
use crate::Result;

/// Dense kernels the coordinator needs, supplied by a backend.
pub trait ComputeBackend: Send + Sync {
    /// Backend name for reports (`"native"`, `"pjrt"`).
    fn name(&self) -> &str;

    /// `g(W·Y)`: fused matmul + ReLU layer forward. `W` is `n×d`,
    /// `Y` is `d×J`.
    fn layer_forward(&self, w: &Matrix, y: &Matrix) -> Result<Matrix>;

    /// Precompute one layer's node-local ADMM solver from features
    /// `y (n×J_m)`, targets `t (Q×J_m)` and the Lagrangian `μ`.
    fn prepare_layer(&self, y: &Matrix, t: &Matrix, mu: f64) -> Result<Box<dyn LocalSolve>>;

    /// Prediction scores `O·Y`.
    fn output_scores(&self, o: &Matrix, y: &Matrix) -> Result<Matrix>;
}
