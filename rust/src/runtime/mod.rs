//! Compute backends: native `f64` reference and the PJRT artifact path.
//!
//! The coordinator is generic over a [`ComputeBackend`] that supplies the
//! four dense kernels of the dSSFN hot path:
//!
//! 1. `layer_forward` — `g(W·Y)` (L1 Pallas kernel `matmul_relu`),
//! 2. `prepare_layer` — Grams `G = Y Yᵀ + μ⁻¹I`, `T Yᵀ` and the hoisted
//!    `G⁻¹` (L1 kernel `gram`, L2 `gram_inverse`),
//! 3. the per-iteration O-update inside the returned [`LocalSolve`]
//!    (L1 kernel `admm_o_update`),
//! 4. `output_scores` — `O·Y` for prediction.
//!
//! [`NativeBackend`] implements all of it with the crate's own `f64`
//! linalg and doubles as the bit-stable oracle; [`PjrtBackend`] executes
//! the AOT-compiled HLO artifacts produced by `make artifacts` via the
//! PJRT CPU client (`xla` crate). Python never runs at training time.

mod artifact;
mod native;
// The real PJRT path needs the external `xla` crate, which the offline
// build image does not ship. Without the `pjrt` feature a stub with the
// same public surface is compiled instead: `PjrtBackend::start` reports
// the backend as unavailable, and every artifact-dependent caller
// (tests/pjrt_parity.rs, benches/microbench.rs, run_config) already
// handles that error by skipping or surfacing it.
#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
mod pjrt;

pub use artifact::{ArtifactManifest, ManifestEntry};
pub use native::NativeBackend;
pub use pjrt::PjrtBackend;

use crate::admm::LocalSolve;
use crate::linalg::Matrix;
use crate::Result;

/// Dense kernels the coordinator needs, supplied by a backend.
pub trait ComputeBackend: Send + Sync {
    /// Backend name for reports (`"native"`, `"pjrt"`).
    fn name(&self) -> &str;

    /// Parallelism hint: how many threads a *single* kernel call may use
    /// internally. The coordinator sets this from its
    /// [`crate::coordinator::ParallelismBudget`] when there are more
    /// worker threads than nodes, so leftover threads accelerate the
    /// per-node Gram build instead of idling. Implementations must keep
    /// results bit-identical for every hint value (the native backend's
    /// threaded Gram guarantees this); backends with internal
    /// parallelism of their own (PJRT) may ignore it. Default: no-op.
    fn set_intra_threads(&self, _threads: usize) {}

    /// `g(W·Y)`: fused matmul + ReLU layer forward. `W` is `n×d`,
    /// `Y` is `d×J`.
    fn layer_forward(&self, w: &Matrix, y: &Matrix) -> Result<Matrix>;

    /// Precompute one layer's node-local ADMM solver from features
    /// `y (n×J_m)`, targets `t (Q×J_m)` and the Lagrangian `μ`.
    fn prepare_layer(&self, y: &Matrix, t: &Matrix, mu: f64) -> Result<Box<dyn LocalSolve>>;

    /// Prediction scores `O·Y`.
    fn output_scores(&self, o: &Matrix, y: &Matrix) -> Result<Matrix>;
}
