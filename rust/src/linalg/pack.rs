//! Panel-packed, register-blocked matrix kernels — the crate's FLOP
//! engine.
//!
//! Two design constraints shape everything here:
//!
//! 1. **Throughput without `-ffast-math`.** Rust never reassociates
//!    floating-point reductions, so a k-loop that feeds a *single*
//!    accumulator is latency-bound (one add every ~4 cycles). The kernels
//!    therefore keep an `MR×NR` (GEMM) or `TR×TR` (SYRK) block of
//!    *independent* accumulator chains live in registers: enough ILP to
//!    saturate the FP ports, while the compiler is still free to
//!    vectorize across the `NR` output columns (a map, not a reduction,
//!    hence legal without fast-math).
//! 2. **Bit-exact, partition-independent results.** Every output element
//!    is produced by one sequential chain over the reduction index `p`
//!    in ascending order, starting from the value already in `C`. For
//!    [`gemm_nn`] this is the *same* chain the classic `i-k-j` axpy
//!    kernel produced, so the packed kernel is bit-identical to its
//!    predecessor on every input. For [`syrk_band`] the chain depends
//!    only on `(i, j, k)` — never on which row band or tile computed the
//!    element — which is what lets [`syrk_mt`] fan the Gram build out
//!    over threads with **zero** floating-point drift versus the
//!    sequential build (the coordinator's bit-equivalence tests pin
//!    this).
//!
//! `B` is packed into `NR`-wide column panels (contiguous per `p`) from a
//! **thread-local arena** that is grown once and reused, so steady-state
//! GEMM calls perform no heap allocation — part of the zero-allocation
//! ADMM hot-path contract (see `admm::Workspace`).

use std::cell::RefCell;

/// Register-tile rows of the GEMM micro-kernel.
const MR: usize = 4;
/// Register-tile columns of the GEMM micro-kernel (two 4-lane vectors).
const NR: usize = 8;
/// Cache block along the reduction dimension.
const KC: usize = 256;
/// Register-tile order of the SYRK micro-kernel.
const TR: usize = 4;

thread_local! {
    /// Per-thread packing arena; grows monotonically, never shrinks.
    static PACK_ARENA: RefCell<Vec<f64>> = RefCell::new(Vec::new());
}

/// `C[m×n] += A[m×k] · B[k×n]`, panel-packed and register-blocked.
///
/// Accumulates into `C` (callers zero it first, as with the kernel this
/// replaced). Per-element accumulation order is a single chain over `p`
/// ascending — bit-identical to the classic blocked `i-k-j` axpy loop.
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let panels = n.div_ceil(NR);
    let kc_max = KC.min(k);
    PACK_ARENA.with(|arena| {
        let mut arena = arena.borrow_mut();
        let need = panels * NR * kc_max;
        if arena.len() < need {
            arena.resize(need, 0.0);
        }
        let buf = &mut arena[..];
        for kb in (0..k).step_by(KC) {
            let kc = KC.min(k - kb);
            pack_b(&b[kb * n..], n, kc, buf);
            for i0 in (0..m).step_by(MR) {
                let mr = MR.min(m - i0);
                // A sub-view starting at row i0, column kb (row stride k).
                let asub = &a[i0 * k + kb..];
                for pj in 0..panels {
                    let j0 = pj * NR;
                    let w = NR.min(n - j0);
                    let panel = &buf[pj * NR * kc..pj * NR * kc + kc * NR];
                    let csub = &mut c[i0 * n + j0..];
                    if mr == MR && w == NR {
                        kernel_full(kc, asub, k, panel, csub, n);
                    } else {
                        kernel_edge(mr, w, kc, asub, k, panel, csub, n);
                    }
                }
            }
        }
    });
}

/// Pack `kc` rows of `B` (row stride `n`) into `NR`-wide column panels:
/// `buf[panel][p][lane]`, short final panels zero-padded.
fn pack_b(b: &[f64], n: usize, kc: usize, buf: &mut [f64]) {
    let panels = n.div_ceil(NR);
    for pj in 0..panels {
        let j0 = pj * NR;
        let w = NR.min(n - j0);
        let dst = &mut buf[pj * NR * kc..(pj + 1) * NR * kc];
        for p in 0..kc {
            let src = &b[p * n + j0..p * n + j0 + w];
            dst[p * NR..p * NR + w].copy_from_slice(src);
            for x in &mut dst[p * NR + w..(p + 1) * NR] {
                *x = 0.0;
            }
        }
    }
}

/// Full `MR×NR` register tile: `MR·NR` independent accumulator chains,
/// `C` loaded once before the `p` loop and stored once after it.
#[inline]
#[allow(clippy::too_many_arguments)]
fn kernel_full(kc: usize, a: &[f64], lda: usize, panel: &[f64], c: &mut [f64], ldc: usize) {
    let mut acc = [[0.0f64; NR]; MR];
    for (r, row) in acc.iter_mut().enumerate() {
        row.copy_from_slice(&c[r * ldc..r * ldc + NR]);
    }
    for p in 0..kc {
        let bp = &panel[p * NR..(p + 1) * NR];
        for r in 0..MR {
            let av = a[r * lda + p];
            let row = &mut acc[r];
            for j in 0..NR {
                row[j] += av * bp[j];
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        c[r * ldc..r * ldc + NR].copy_from_slice(row);
    }
}

/// Edge tile (`mr ≤ MR`, `w ≤ NR`): identical per-element chains, runtime
/// bounds.
#[inline]
#[allow(clippy::too_many_arguments)]
fn kernel_edge(
    mr: usize,
    w: usize,
    kc: usize,
    a: &[f64],
    lda: usize,
    panel: &[f64],
    c: &mut [f64],
    ldc: usize,
) {
    let mut acc = [[0.0f64; NR]; MR];
    for r in 0..mr {
        acc[r][..w].copy_from_slice(&c[r * ldc..r * ldc + w]);
    }
    for p in 0..kc {
        let bp = &panel[p * NR..(p + 1) * NR];
        for r in 0..mr {
            let av = a[r * lda + p];
            let row = &mut acc[r];
            for j in 0..w {
                row[j] += av * bp[j];
            }
        }
    }
    for r in 0..mr {
        c[r * ldc..r * ldc + w].copy_from_slice(&acc[r][..w]);
    }
}

/// Single-chain dot product over `p` ascending — the canonical
/// per-element computation every SYRK path (tiled, edge, banded,
/// threaded) reduces to. Deliberately *not* the unrolled 4-way `dot`:
/// one chain keeps the result a pure function of `(row_i, row_j)`.
#[inline]
fn dot_chain(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        s += x * y;
    }
    s
}

/// Lower-triangle rows `[i0, i1)` of `C[m×m] = A[m×k]·Aᵀ`, written into
/// `cband` (whose row 0 is global row `i0`). No mirroring — see
/// [`mirror_lower`]. Every element is [`dot_chain`]`(row_i, row_j)`
/// exactly, so the output is independent of the band partition.
pub fn syrk_band(m: usize, k: usize, a: &[f64], cband: &mut [f64], i0: usize, i1: usize) {
    debug_assert!(i0 <= i1 && i1 <= m);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(cband.len(), (i1 - i0) * m);
    let mut i = i0;
    while i < i1 {
        let ih = TR.min(i1 - i);
        // Full TR-wide column tiles strictly below the tile diagonal.
        let mut j0 = 0;
        while j0 + TR <= i {
            syrk_tile(k, a, i, ih, j0, cband, i0, m);
            j0 += TR;
        }
        // Diagonal fringe: per-row scalar chains up to and including the
        // diagonal element.
        for r in 0..ih {
            let gi = i + r;
            let arow = &a[gi * k..(gi + 1) * k];
            for j in j0..=gi {
                let v = dot_chain(arow, &a[j * k..(j + 1) * k]);
                cband[(gi - i0) * m + j] = v;
            }
        }
        i += ih;
    }
}

/// `ih×TR` SYRK register tile: rows `i..i+ih` against rows `j0..j0+TR`,
/// all strictly below the diagonal (caller guarantees `j0+TR ≤ i`).
#[inline]
#[allow(clippy::too_many_arguments)]
fn syrk_tile(
    k: usize,
    a: &[f64],
    i: usize,
    ih: usize,
    j0: usize,
    cband: &mut [f64],
    i0: usize,
    m: usize,
) {
    let mut acc = [[0.0f64; TR]; TR];
    for p in 0..k {
        let bs = [
            a[j0 * k + p],
            a[(j0 + 1) * k + p],
            a[(j0 + 2) * k + p],
            a[(j0 + 3) * k + p],
        ];
        for r in 0..ih {
            let av = a[(i + r) * k + p];
            let row = &mut acc[r];
            for s in 0..TR {
                row[s] += av * bs[s];
            }
        }
    }
    for r in 0..ih {
        let base = (i + r - i0) * m + j0;
        cband[base..base + TR].copy_from_slice(&acc[r]);
    }
}

/// Mirror the lower triangle of `C[m×m]` into the upper triangle.
pub fn mirror_lower(m: usize, c: &mut [f64]) {
    // Blocked for cache friendliness on large Grams.
    const B: usize = 32;
    for ib in (0..m).step_by(B) {
        for jb in (0..ib + B).step_by(B) {
            for i in ib..(ib + B).min(m) {
                for j in jb..(jb + B).min(i) {
                    c[j * m + i] = c[i * m + j];
                }
            }
        }
    }
}

/// `C[m×m] = A[m×k]·Aᵀ` (full, sequential). `C` is written, not
/// accumulated; callers pass a zeroed buffer.
pub fn syrk(m: usize, k: usize, a: &[f64], c: &mut [f64]) {
    debug_assert_eq!(c.len(), m * m);
    syrk_band(m, k, a, c, 0, m);
    mirror_lower(m, c);
}

/// Threaded `C = A·Aᵀ`: contiguous row bands sized by triangle area
/// (`i_t ∝ m·√(t/T)`) so each worker owns an equal share of the FLOPs.
/// Bit-identical to [`syrk`] for every `threads` value — each element is
/// the same [`dot_chain`] regardless of the partition.
pub fn syrk_mt(m: usize, k: usize, a: &[f64], c: &mut [f64], threads: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * m);
    let threads = threads.max(1).min(m.max(1));
    // Below ~64 rows the spawn cost outweighs the win; the result is
    // identical either way, so this threshold is purely a perf knob.
    if threads == 1 || m < 64 {
        syrk(m, k, a, c);
        return;
    }
    let mut bounds: Vec<usize> = (0..=threads)
        .map(|t| ((m as f64) * (t as f64 / threads as f64).sqrt()).round() as usize)
        .collect();
    bounds[0] = 0;
    bounds[threads] = m;
    for t in 1..=threads {
        let lo = bounds[t - 1];
        bounds[t] = bounds[t].clamp(lo, m);
    }
    std::thread::scope(|scope| {
        // Reborrow (not move) so `c` is usable again for the mirror pass.
        let mut rest: &mut [f64] = &mut *c;
        for t in 0..threads {
            let (i0, i1) = (bounds[t], bounds[t + 1]);
            if i1 <= i0 {
                continue;
            }
            let tail = std::mem::take(&mut rest);
            let (band, tail) = tail.split_at_mut((i1 - i0) * m);
            rest = tail;
            scope.spawn(move || syrk_band(m, k, a, band, i0, i1));
        }
    });
    mirror_lower(m, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    fn rand_buf(rng: &mut impl Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    /// The pre-pack reference kernel: classic blocked i-k-j axpy loop.
    /// The packed kernel must reproduce it bit-for-bit.
    fn ikj_reference(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * n..(i + 1) * n];
            for p in 0..k {
                let aip = arow[p];
                let brow = &b[p * n..(p + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aip * bv;
                }
            }
        }
    }

    #[test]
    fn packed_gemm_bit_identical_to_ikj_reference() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(21);
        for &(m, k, n) in &[
            (1, 1, 1),
            (4, 8, 8),
            (5, 9, 11),
            (10, 120, 120),
            (13, 300, 7),
            (64, 257, 40),
        ] {
            let a = rand_buf(&mut rng, m * k);
            let b = rand_buf(&mut rng, k * n);
            let mut c_new = vec![0.0; m * n];
            let mut c_ref = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c_new);
            ikj_reference(m, k, n, &a, &b, &mut c_ref);
            assert_eq!(c_new, c_ref, "drift at m={m} k={k} n={n}");
        }
    }

    #[test]
    fn packed_gemm_accumulates_into_c() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(22);
        let (m, k, n) = (6, 20, 10);
        let a = rand_buf(&mut rng, m * k);
        let b = rand_buf(&mut rng, k * n);
        let seed = rand_buf(&mut rng, m * n);
        let mut c_new = seed.clone();
        let mut c_ref = seed.clone();
        gemm_nn(m, k, n, &a, &b, &mut c_new);
        ikj_reference(m, k, n, &a, &b, &mut c_ref);
        assert_eq!(c_new, c_ref);
    }

    #[test]
    fn syrk_band_partition_independent() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(23);
        let (m, k) = (37, 80);
        let a = rand_buf(&mut rng, m * k);
        let mut full = vec![0.0; m * m];
        syrk(m, k, &a, &mut full);
        // Rebuild from three uneven bands; must match bit-for-bit.
        let mut banded = vec![0.0; m * m];
        for &(i0, i1) in &[(0usize, 5usize), (5, 23), (23, 37)] {
            let mut band = vec![0.0; (i1 - i0) * m];
            syrk_band(m, k, &a, &mut band, i0, i1);
            banded[i0 * m..i1 * m].copy_from_slice(&band);
        }
        mirror_lower(m, &mut banded);
        assert_eq!(full, banded);
    }

    #[test]
    fn syrk_mt_bit_identical_to_sequential() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(24);
        let (m, k) = (97, 64); // above the threading threshold
        let a = rand_buf(&mut rng, m * k);
        let mut seq = vec![0.0; m * m];
        syrk(m, k, &a, &mut seq);
        for threads in [2usize, 3, 8] {
            let mut par = vec![0.0; m * m];
            syrk_mt(m, k, &a, &mut par, threads);
            assert_eq!(seq, par, "threads={threads}");
        }
    }

    #[test]
    fn syrk_matches_gemm_numerically() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(25);
        let (m, k) = (23, 57);
        let a = rand_buf(&mut rng, m * k);
        let mut c = vec![0.0; m * m];
        syrk(m, k, &a, &mut c);
        for i in 0..m {
            for j in 0..m {
                let expect = dot_chain(&a[i * k..(i + 1) * k], &a[j * k..(j + 1) * k]);
                assert!((c[i * m + j] - expect).abs() < 1e-12);
                assert_eq!(c[i * m + j], c[j * m + i]);
            }
        }
    }
}
