//! Cholesky factorization and SPD solves.
//!
//! The ADMM O-update solves `O · (Y Yᵀ + μ⁻¹ I) = (T Yᵀ + μ⁻¹(Z − Λ))`
//! where the system matrix is symmetric positive-definite and **constant
//! across all `K` ADMM iterations of a layer**. We therefore factor once
//! per layer ([`CholeskyFactor::new`]) and reuse the factor in every
//! iteration ([`CholeskyFactor::solve_xa`]), turning the inner loop into
//! pure GEMM + triangular solves.

use super::Matrix;
use crate::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L·Lᵀ`.
#[derive(Debug, Clone)]
pub struct CholeskyFactor {
    n: usize,
    /// Row-major lower-triangular factor (upper part zeroed).
    l: Vec<f64>,
}

impl CholeskyFactor {
    /// Factor an SPD matrix. Fails with [`Error::Numerical`] if a pivot is
    /// not strictly positive (matrix not SPD, or catastrophically
    /// ill-conditioned).
    pub fn new(a: &Matrix) -> Result<Self> {
        if a.rows() != a.cols() {
            return Err(Error::Shape(format!(
                "cholesky of non-square {}x{}",
                a.rows(),
                a.cols()
            )));
        }
        let n = a.rows();
        let src = a.as_slice();
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = src[i * n + j];
                // s -= Σ_k<j L[i,k]·L[j,k]
                s -= super::gemm::dot(&l[i * n..i * n + j], &l[j * n..j * n + j]);
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::Numerical(format!(
                            "cholesky: pivot {s:.3e} at row {i} (matrix not SPD)"
                        )));
                    }
                    l[i * n + i] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Ok(Self { n, l })
    }

    /// Order of the factored matrix.
    pub fn order(&self) -> usize {
        self.n
    }

    /// Solve `A·x = b` for a single right-hand side (in place).
    pub fn solve_vec(&self, b: &mut [f64]) -> Result<()> {
        if b.len() != self.n {
            return Err(Error::Shape(format!(
                "solve_vec: rhs len {} != order {}",
                b.len(),
                self.n
            )));
        }
        let n = self.n;
        let l = &self.l;
        // Forward: L·y = b
        for i in 0..n {
            let s = super::gemm::dot(&l[i * n..i * n + i], &b[..i]);
            b[i] = (b[i] - s) / l[i * n + i];
        }
        // Backward: Lᵀ·x = y
        for i in (0..n).rev() {
            let mut s = b[i];
            for k in i + 1..n {
                s -= l[k * n + i] * b[k];
            }
            b[i] = s / l[i * n + i];
        }
        Ok(())
    }

    /// Solve `X·A = B` (i.e. `X = B·A⁻¹`) row-by-row: each row of `B` is an
    /// independent RHS of `A·xᵀ = bᵀ` because `A` is symmetric. This is the
    /// exact shape of the ADMM O-update (`B` is `Q×n`, `A` is `n×n`).
    pub fn solve_xa(&self, b: &Matrix) -> Result<Matrix> {
        if b.cols() != self.n {
            return Err(Error::Shape(format!(
                "solve_xa: B has {} cols, factor order {}",
                b.cols(),
                self.n
            )));
        }
        let mut out = b.clone();
        for r in 0..out.rows() {
            self.solve_vec(out.row_mut(r))?;
        }
        Ok(out)
    }

    /// Dense inverse `A⁻¹` (the hoisted operand of the ADMM inner loop
    /// and the PJRT O-update artifact). `A` is symmetric, so solving
    /// `X·A = I` row-by-row yields the inverse with contiguous row
    /// access.
    pub fn inverse(&self) -> Matrix {
        self.solve_xa(&Matrix::identity(self.n))
            .expect("identity matches factor order")
    }

    /// log-determinant of `A` (sum of log of squared diagonal of `L`).
    pub fn log_det(&self) -> f64 {
        (0..self.n)
            .map(|i| self.l[i * self.n + i].ln())
            .sum::<f64>()
            * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    /// Random SPD matrix A = GᵀG + n·I.
    fn rand_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        let g = Matrix::from_fn(n, n, |_, _| rng.uniform(-1.0, 1.0));
        let mut a = g.gram();
        a.add_diag(n as f64).unwrap();
        a
    }

    #[test]
    fn factor_reconstructs_matrix() {
        let a = rand_spd(17, 5);
        let f = a.cholesky().unwrap();
        // Reconstruct L·Lᵀ.
        let l = Matrix::from_vec(17, 17, f.l.clone()).unwrap();
        let rec = l.matmul_transb(&l).unwrap();
        assert!(rec.max_abs_diff(&a) < 1e-9);
        assert_eq!(f.order(), 17);
    }

    #[test]
    fn rejects_non_spd_and_non_square() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]).unwrap(); // indefinite
        assert!(matches!(a.cholesky(), Err(Error::Numerical(_))));
        assert!(Matrix::zeros(2, 3).cholesky().is_err());
    }

    #[test]
    fn solve_vec_residual_small() {
        let a = rand_spd(31, 6);
        let f = a.cholesky().unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(7);
        let x_true: Vec<f64> = (0..31).map(|_| rng.uniform(-2.0, 2.0)).collect();
        // b = A·x_true
        let mut b = vec![0.0; 31];
        for i in 0..31 {
            b[i] = super::super::gemm::dot(a.row(i), &x_true);
        }
        f.solve_vec(&mut b).unwrap();
        for (xi, ti) in b.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-8);
        }
        assert!(f.solve_vec(&mut [0.0; 3]).is_err());
    }

    #[test]
    fn solve_xa_matches_inverse_product() {
        let a = rand_spd(12, 8);
        let f = a.cholesky().unwrap();
        let mut rng = Xoshiro256StarStar::seed_from_u64(9);
        let b = Matrix::from_fn(5, 12, |_, _| rng.uniform(-1.0, 1.0));
        let x = f.solve_xa(&b).unwrap();
        // Check X·A = B.
        let xa = x.matmul(&a).unwrap();
        assert!(xa.max_abs_diff(&b) < 1e-8);
        // And against the explicit inverse.
        let via_inv = b.matmul(&f.inverse()).unwrap();
        assert!(x.max_abs_diff(&via_inv) < 1e-8);
        assert!(f.solve_xa(&Matrix::zeros(2, 5)).is_err());
    }

    #[test]
    fn inverse_is_two_sided() {
        let a = rand_spd(9, 10);
        let inv = a.cholesky().unwrap().inverse();
        let left = inv.matmul(&a).unwrap();
        let right = a.matmul(&inv).unwrap();
        let eye = Matrix::identity(9);
        assert!(left.max_abs_diff(&eye) < 1e-9);
        assert!(right.max_abs_diff(&eye) < 1e-9);
    }

    #[test]
    fn log_det_matches_diagonal_matrix() {
        let mut a = Matrix::zeros(4, 4);
        for (i, v) in [2.0, 3.0, 4.0, 5.0].iter().enumerate() {
            a.set(i, i, *v);
        }
        let f = a.cholesky().unwrap();
        let expect = (2.0f64 * 3.0 * 4.0 * 5.0).ln();
        assert!((f.log_det() - expect).abs() < 1e-12);
    }
}
