//! Free-standing neural-net helper ops over [`Matrix`].

use super::Matrix;
use crate::{Error, Result};

/// Owned element-wise ReLU.
pub fn relu(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    out.relu_inplace();
    out
}

/// Build a `Q×J` one-hot target matrix from class labels.
pub fn one_hot(labels: &[usize], num_classes: usize) -> Result<Matrix> {
    let mut t = Matrix::zeros(num_classes, labels.len());
    for (j, &cls) in labels.iter().enumerate() {
        if cls >= num_classes {
            return Err(Error::Data(format!(
                "label {cls} out of range for {num_classes} classes"
            )));
        }
        t.set(cls, j, 1.0);
    }
    Ok(t)
}

/// Classification accuracy of prediction scores `S (Q×J)` against labels.
pub fn accuracy_from_predictions(scores: &Matrix, labels: &[usize]) -> Result<f64> {
    if scores.cols() != labels.len() {
        return Err(Error::Shape(format!(
            "accuracy: {} predictions vs {} labels",
            scores.cols(),
            labels.len()
        )));
    }
    if labels.is_empty() {
        return Ok(0.0);
    }
    let pred = scores.argmax_per_col();
    let correct = pred.iter().zip(labels).filter(|(p, l)| p == l).count();
    Ok(correct as f64 / labels.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_leaves_original_untouched() {
        let a = Matrix::from_rows(&[vec![-1.0, 2.0]]).unwrap();
        let r = relu(&a);
        assert_eq!(r.get(0, 0), 0.0);
        assert_eq!(a.get(0, 0), -1.0);
    }

    #[test]
    fn one_hot_layout() {
        let t = one_hot(&[2, 0, 1], 3).unwrap();
        assert_eq!(t.shape(), (3, 3));
        assert_eq!(t.get(2, 0), 1.0);
        assert_eq!(t.get(0, 1), 1.0);
        assert_eq!(t.get(1, 2), 1.0);
        assert_eq!(t.as_slice().iter().sum::<f64>(), 3.0);
        assert!(one_hot(&[3], 3).is_err());
    }

    #[test]
    fn accuracy_counts_argmax_hits() {
        // scores: 2 classes × 4 samples
        let s = Matrix::from_rows(&[
            vec![0.9, 0.1, 0.6, 0.2],
            vec![0.1, 0.9, 0.4, 0.8],
        ])
        .unwrap();
        let acc = accuracy_from_predictions(&s, &[0, 1, 0, 0]).unwrap();
        assert!((acc - 0.75).abs() < 1e-12);
        assert!(accuracy_from_predictions(&s, &[0]).is_err());
        assert_eq!(accuracy_from_predictions(&Matrix::zeros(2, 0), &[]).unwrap(), 0.0);
    }
}
