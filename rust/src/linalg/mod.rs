//! Dense linear-algebra substrate (no external BLAS).
//!
//! Everything dSSFN computes is dense `f64` matrix algebra over modest
//! shapes (`n ≤ ~3000`, `Q ≤ ~102`, shard sizes in the thousands), so a
//! compact cache-blocked implementation is both sufficient and fully
//! portable. This module is used by
//!
//! * the **native reference path** (oracle for the PJRT artifacts),
//! * the **mixing-matrix algebra** of the network simulator,
//! * the **DGD baseline**, and
//! * the centralized SSFN trainer.
//!
//! Layout is row-major. The hot kernels live in [`pack`] (panel-packed,
//! register-blocked GEMM/SYRK micro-kernels fed from a thread-local
//! packing arena — allocation-free in steady state and bit-identical to
//! the naive loop order per element), re-exported through [`gemm`], and
//! in [`cholesky`] (SPD factorization used to hoist the ADMM Gram
//! inverse out of the inner loop). The hot-path entry points for the
//! zero-allocation ADMM iteration are [`Matrix::matmul_into`] (write
//! into a caller-owned buffer) and [`Matrix::gram_threaded`] (row-banded
//! multi-threaded Gram build, bit-identical to [`Matrix::gram`] for
//! every thread count).

mod cholesky;
mod gemm;
mod ops;
mod pack;

pub use cholesky::CholeskyFactor;
pub use gemm::dot;

use crate::{Error, Result};

/// Dense row-major `f64` matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix of the given shape.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a generator function `(row, col) -> value`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_vec: buffer has {} elements, expected {rows}x{cols}={}",
                data.len(),
                rows * cols
            )));
        }
        Ok(Self { rows, cols, data })
    }

    /// Build from nested rows (for tests / small literals).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        if rows.iter().any(|row| row.len() != c) {
            return Err(Error::Shape("from_rows: ragged input".into()));
        }
        Ok(Self {
            rows: r,
            cols: c,
            data: rows.iter().flatten().copied().collect(),
        })
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the raw row-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the raw row-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out into a vector.
    pub fn col_to_vec(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Transpose (allocates).
    pub fn transpose(&self) -> Self {
        let mut t = Self::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// `self @ other`.
    pub fn matmul(&self, other: &Self) -> Result<Self> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul: {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Self::zeros(self.rows, other.cols);
        gemm::gemm_nn(
            self.rows, self.cols, other.cols,
            &self.data, &other.data, &mut out.data,
        );
        Ok(out)
    }

    /// `self @ other` written into `out` without allocating. `out` is
    /// overwritten (zeroed, then accumulated) — the zero-allocation form
    /// of [`Matrix::matmul`] used by the ADMM hot path; both produce
    /// bit-identical values.
    pub fn matmul_into(&self, other: &Self, out: &mut Self) -> Result<()> {
        if self.cols != other.rows || out.rows != self.rows || out.cols != other.cols {
            return Err(Error::Shape(format!(
                "matmul_into: {}x{} @ {}x{} -> {}x{}",
                self.rows, self.cols, other.rows, other.cols, out.rows, out.cols
            )));
        }
        out.fill_zero();
        gemm::gemm_nn(
            self.rows, self.cols, other.cols,
            &self.data, &other.data, &mut out.data,
        );
        Ok(())
    }

    /// `self @ otherᵀ` without materializing the transpose.
    pub fn matmul_transb(&self, other: &Self) -> Result<Self> {
        if self.cols != other.cols {
            return Err(Error::Shape(format!(
                "matmul_transb: {}x{} @ ({}x{})ᵀ",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Self::zeros(self.rows, other.rows);
        gemm::gemm_nt(
            self.rows, self.cols, other.rows,
            &self.data, &other.data, &mut out.data,
        );
        Ok(out)
    }

    /// Gram matrix `self @ selfᵀ` (symmetric fast path).
    pub fn gram(&self) -> Self {
        let mut out = Self::zeros(self.rows, self.rows);
        gemm::syrk(self.rows, self.cols, &self.data, &mut out.data);
        out
    }

    /// Gram matrix built across `threads` row bands. Bit-identical to
    /// [`Matrix::gram`] for every thread count (each element is the same
    /// single-chain dot regardless of the partition), so the coordinator
    /// can hand leftover worker threads to the per-node Gram build
    /// without breaking centralized-equivalence determinism.
    pub fn gram_threaded(&self, threads: usize) -> Self {
        let mut out = Self::zeros(self.rows, self.rows);
        pack::syrk_mt(self.rows, self.cols, &self.data, &mut out.data, threads);
        out
    }

    /// Element-wise in-place: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Self) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "axpy: {:?} += {:?}",
                self.shape(),
                other.shape()
            )));
        }
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
        Ok(())
    }

    /// Owned element-wise sum.
    pub fn add(&self, other: &Self) -> Result<Self> {
        let mut out = self.clone();
        out.axpy(1.0, other)?;
        Ok(out)
    }

    /// Owned element-wise difference.
    pub fn sub(&self, other: &Self) -> Result<Self> {
        let mut out = self.clone();
        out.axpy(-1.0, other)?;
        Ok(out)
    }

    /// In-place scaling.
    pub fn scale_inplace(&mut self, alpha: f64) {
        for v in &mut self.data {
            *v *= alpha;
        }
    }

    /// Owned scaling.
    pub fn scale(&self, alpha: f64) -> Self {
        let mut out = self.clone();
        out.scale_inplace(alpha);
        out
    }

    /// Set all entries to zero (buffer reuse in hot loops).
    pub fn fill_zero(&mut self) {
        self.data.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Copy `other` into `self` (shapes must match) without reallocating.
    pub fn copy_from(&mut self, other: &Self) -> Result<()> {
        if self.shape() != other.shape() {
            return Err(Error::Shape(format!(
                "copy_from: {:?} <- {:?}",
                self.shape(),
                other.shape()
            )));
        }
        self.data.copy_from_slice(&other.data);
        Ok(())
    }

    /// Add `alpha` to the diagonal in place (`self += alpha * I`).
    pub fn add_diag(&mut self, alpha: f64) -> Result<()> {
        if self.rows != self.cols {
            return Err(Error::Shape(format!(
                "add_diag on non-square {}x{}",
                self.rows, self.cols
            )));
        }
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
        Ok(())
    }

    /// Frobenius norm.
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Squared Frobenius norm.
    pub fn frobenius_norm_sq(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>()
    }

    /// Projection onto the Frobenius ball of radius `eps` — the paper's
    /// `P_ε(Z)`: rescale iff `‖Z‖_F > eps`.
    pub fn project_frobenius(&mut self, eps: f64) {
        let norm = self.frobenius_norm();
        if norm > eps && norm > 0.0 {
            self.scale_inplace(eps / norm);
        }
    }

    /// Maximum absolute element-wise difference (∞-norm of the difference).
    pub fn max_abs_diff(&self, other: &Self) -> f64 {
        debug_assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Element-wise ReLU in place.
    pub fn relu_inplace(&mut self) {
        for v in &mut self.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Self) -> Result<Self> {
        if self.rows != other.rows {
            return Err(Error::Shape(format!(
                "hcat: {} vs {} rows",
                self.rows, other.rows
            )));
        }
        let mut out = Self::zeros(self.rows, self.cols + other.cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        Ok(out)
    }

    /// Vertical concatenation `[self ; other]`.
    pub fn vcat(&self, other: &Self) -> Result<Self> {
        if self.cols != other.cols {
            return Err(Error::Shape(format!(
                "vcat: {} vs {} cols",
                self.cols, other.cols
            )));
        }
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Ok(Self {
            rows: self.rows + other.rows,
            cols: self.cols,
            data,
        })
    }

    /// Select a contiguous block of columns `[c0, c1)` (copies).
    pub fn col_block(&self, c0: usize, c1: usize) -> Result<Self> {
        if c0 > c1 || c1 > self.cols {
            return Err(Error::Shape(format!(
                "col_block [{c0},{c1}) of {}x{}",
                self.rows, self.cols
            )));
        }
        let w = c1 - c0;
        let mut out = Self::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        Ok(out)
    }

    /// Cast to `f32` row-major (for PJRT literals).
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from an `f32` row-major buffer (from PJRT literals).
    pub fn from_f32_slice(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "from_f32_slice: {} elements for {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Self {
            rows,
            cols,
            data: data.iter().map(|&v| v as f64).collect(),
        })
    }

    /// Cholesky factorization of an SPD matrix (see [`CholeskyFactor`]).
    pub fn cholesky(&self) -> Result<CholeskyFactor> {
        CholeskyFactor::new(self)
    }

    /// Index of the max element in each column (classification argmax).
    pub fn argmax_per_col(&self) -> Vec<usize> {
        let mut out = vec![0usize; self.cols];
        let mut best = vec![f64::NEG_INFINITY; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            for c in 0..self.cols {
                if row[c] > best[c] {
                    best[c] = row[c];
                    out[c] = r;
                }
            }
        }
        out
    }
}

pub use ops::{accuracy_from_predictions, one_hot, relu};

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: &[Vec<f64>]) -> Matrix {
        Matrix::from_rows(rows).unwrap()
    }

    #[test]
    fn constructors_and_accessors() {
        let a = Matrix::zeros(2, 3);
        assert_eq!(a.shape(), (2, 3));
        assert_eq!(a.as_slice(), &[0.0; 6]);

        let i = Matrix::identity(3);
        assert_eq!(i.get(1, 1), 1.0);
        assert_eq!(i.get(1, 2), 0.0);

        let f = Matrix::from_fn(2, 2, |r, c| (r * 10 + c) as f64);
        assert_eq!(f.get(1, 0), 10.0);

        assert!(Matrix::from_vec(2, 2, vec![1.0; 3]).is_err());
        assert!(Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = m(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let t = a.transpose();
        assert_eq!(t.shape(), (3, 2));
        assert_eq!(t.get(2, 1), 6.0);
        assert_eq!(t.transpose(), a);
    }

    #[test]
    fn matmul_small() {
        let a = m(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = m(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c, m(&[vec![19.0, 22.0], vec![43.0, 50.0]]));
        assert!(a.matmul(&Matrix::zeros(3, 3)).is_err());
    }

    #[test]
    fn matmul_transb_matches_explicit_transpose() {
        let a = m(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        let b = m(&[vec![1.0, 0.5, -1.0], vec![2.0, -2.0, 0.0]]);
        let fast = a.matmul_transb(&b).unwrap();
        let slow = a.matmul(&b.transpose()).unwrap();
        assert!(fast.max_abs_diff(&slow) < 1e-12);
    }

    #[test]
    fn gram_matches_matmul() {
        let a = m(&[vec![1.0, 2.0, 3.0], vec![-1.0, 0.0, 2.0]]);
        let g = a.gram();
        let explicit = a.matmul(&a.transpose()).unwrap();
        assert!(g.max_abs_diff(&explicit) < 1e-12);
        // Symmetry.
        assert_eq!(g.get(0, 1), g.get(1, 0));
    }

    #[test]
    fn arithmetic_ops() {
        let a = m(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = m(&[vec![0.5, 0.5], vec![0.5, 0.5]]);
        assert_eq!(a.add(&b).unwrap().get(0, 0), 1.5);
        assert_eq!(a.sub(&b).unwrap().get(1, 1), 3.5);
        assert_eq!(a.scale(2.0).get(1, 0), 6.0);
        let mut c = a.clone();
        c.axpy(-1.0, &a).unwrap();
        assert_eq!(c.frobenius_norm(), 0.0);
        assert!(a.add(&Matrix::zeros(3, 3)).is_err());
        let mut d = a.clone();
        d.add_diag(10.0).unwrap();
        assert_eq!(d.get(0, 0), 11.0);
        assert_eq!(d.get(0, 1), 2.0);
        assert!(Matrix::zeros(2, 3).add_diag(1.0).is_err());
    }

    #[test]
    fn frobenius_projection() {
        let mut a = m(&[vec![3.0, 0.0], vec![0.0, 4.0]]); // ‖A‖_F = 5
        let mut b = a.clone();
        a.project_frobenius(10.0); // inside the ball: untouched
        assert_eq!(a, m(&[vec![3.0, 0.0], vec![0.0, 4.0]]));
        b.project_frobenius(2.5); // outside: rescaled to the boundary
        assert!((b.frobenius_norm() - 2.5).abs() < 1e-12);
        // Direction preserved.
        assert!((b.get(0, 0) / b.get(1, 1) - 3.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn relu_and_concat() {
        let mut a = m(&[vec![-1.0, 2.0], vec![0.5, -3.0]]);
        a.relu_inplace();
        assert_eq!(a, m(&[vec![0.0, 2.0], vec![0.5, 0.0]]));

        let b = m(&[vec![1.0], vec![2.0]]);
        let h = a.hcat(&b).unwrap();
        assert_eq!(h.shape(), (2, 3));
        assert_eq!(h.get(1, 2), 2.0);

        let v = a.vcat(&m(&[vec![9.0, 9.0]])).unwrap();
        assert_eq!(v.shape(), (3, 2));
        assert_eq!(v.get(2, 0), 9.0);

        assert!(a.hcat(&Matrix::zeros(3, 1)).is_err());
        assert!(a.vcat(&Matrix::zeros(1, 3)).is_err());
    }

    #[test]
    fn col_block_and_argmax() {
        let a = m(&[vec![1.0, 5.0, 3.0], vec![4.0, 2.0, 6.0]]);
        let blk = a.col_block(1, 3).unwrap();
        assert_eq!(blk, m(&[vec![5.0, 3.0], vec![2.0, 6.0]]));
        assert!(a.col_block(2, 4).is_err());
        assert_eq!(a.argmax_per_col(), vec![1, 0, 1]);
    }

    #[test]
    fn matmul_into_matches_matmul_bitwise() {
        let a = Matrix::from_fn(7, 13, |r, c| ((r * 31 + c * 17) as f64).sin());
        let b = Matrix::from_fn(13, 9, |r, c| ((r * 7 + c * 3) as f64).cos());
        let owned = a.matmul(&b).unwrap();
        let mut out = Matrix::from_fn(7, 9, |_, _| 99.0); // stale contents overwritten
        a.matmul_into(&b, &mut out).unwrap();
        assert_eq!(out, owned);
        let mut wrong = Matrix::zeros(7, 8);
        assert!(a.matmul_into(&b, &mut wrong).is_err());
        assert!(b.matmul_into(&b, &mut out).is_err());
    }

    #[test]
    fn gram_threaded_matches_gram_bitwise() {
        // Big enough to clear the syrk_mt threading threshold.
        let a = Matrix::from_fn(80, 50, |r, c| ((r * 13 + c * 29) as f64).sin());
        let seq = a.gram();
        for threads in [1usize, 2, 5] {
            let par = a.gram_threaded(threads);
            assert_eq!(par, seq, "threads={threads}");
        }
    }

    #[test]
    fn f32_roundtrip() {
        let a = m(&[vec![1.25, -2.5], vec![3.0, 0.0]]);
        let f = a.to_f32_vec();
        let back = Matrix::from_f32_slice(2, 2, &f).unwrap();
        assert!(a.max_abs_diff(&back) < 1e-7);
        assert!(Matrix::from_f32_slice(2, 2, &[0.0; 3]).is_err());
    }
}
