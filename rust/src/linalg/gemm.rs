//! Cache-blocked GEMM kernels over row-major `f64` buffers.
//!
//! Three variants cover everything the crate needs:
//!
//! * [`gemm_nn`] — `C = A·B`
//! * [`gemm_nt`] — `C = A·Bᵀ` (dot-product form; no transpose materialized)
//! * [`syrk`]    — `C = A·Aᵀ` exploiting symmetry (half the FLOPs)
//!
//! The `nn` kernel uses the classic `i-k-j` loop order with `K`-blocking so
//! the inner loop is a contiguous `axpy` over a row of `B` — this both
//! auto-vectorizes and streams memory. The `nt` kernel is dot-product
//! shaped, which is already contiguous for row-major inputs.
//!
//! These are deliberately single-threaded: in dSSFN the *workers* are the
//! parallelism axis (M node threads), so nested threading inside GEMM
//! would oversubscribe cores and distort the Fig-4 timing model.

/// Block size along the reduction dimension for `gemm_nn`.
const KC: usize = 256;
/// Block size along the M dimension.
const MC: usize = 64;

/// `C[m×n] = A[m×k] · B[k×n]` (C is accumulated into; caller zeroes it).
///
/// Register-blocked 4-row micro-kernel: each streamed row of `B` is
/// reused against four rows of `A`, quadrupling the arithmetic per
/// memory access versus the plain `i-k-j` axpy loop (§Perf: ~1.6× at
/// 256³).
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for kb in (0..k).step_by(KC) {
        let kmax = (kb + KC).min(k);
        for mb in (0..m).step_by(MC) {
            let mmax = (mb + MC).min(m);
            let mut i = mb;
            // 4-row micro-kernel.
            while i + 4 <= mmax {
                let (a0, a1, a2, a3) = (
                    &a[i * k..(i + 1) * k],
                    &a[(i + 1) * k..(i + 2) * k],
                    &a[(i + 2) * k..(i + 3) * k],
                    &a[(i + 3) * k..(i + 4) * k],
                );
                // Split the four C rows without overlapping borrows.
                let (c01, c23) = c[i * n..(i + 4) * n].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3) = c23.split_at_mut(n);
                for p in kb..kmax {
                    let (w0, w1, w2, w3) = (a0[p], a1[p], a2[p], a3[p]);
                    let brow = &b[p * n..(p + 1) * n];
                    for jj in 0..n {
                        let bv = brow[jj];
                        c0[jj] += w0 * bv;
                        c1[jj] += w1 * bv;
                        c2[jj] += w2 * bv;
                        c3[jj] += w3 * bv;
                    }
                }
                i += 4;
            }
            // Remainder rows: plain axpy loop.
            while i < mmax {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for p in kb..kmax {
                    let aip = arow[p];
                    let brow = &b[p * n..(p + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aip * bv;
                    }
                }
                i += 1;
            }
        }
    }
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (dot-product form; C accumulated into).
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            crow[j] += dot(arow, brow);
        }
    }
}

/// `C[m×m] = A[m×k] · Aᵀ`, computing only the lower triangle and
/// mirroring. Processes two `i`-rows at a time so each streamed `A[j]`
/// row feeds two dot products (§Perf: ~1.3× on the Gram build).
pub fn syrk(m: usize, k: usize, a: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * m);
    let mut i = 0;
    while i + 2 <= m {
        let r0 = &a[i * k..(i + 1) * k];
        let r1 = &a[(i + 1) * k..(i + 2) * k];
        for j in 0..=i {
            let brow = &a[j * k..(j + 1) * k];
            let (mut s0, mut s1) = (0.0f64, 0.0f64);
            for ((&x0, &x1), &bv) in r0.iter().zip(r1).zip(brow) {
                s0 += x0 * bv;
                s1 += x1 * bv;
            }
            c[i * m + j] = s0;
            c[j * m + i] = s0;
            c[(i + 1) * m + j] = s1;
            c[j * m + i + 1] = s1;
        }
        // The (i+1, i+1) diagonal element not covered by j ≤ i.
        let d = dot(r1, r1);
        c[(i + 1) * m + i + 1] = d;
        i += 2;
    }
    if i < m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..=i {
            let brow = &a[j * k..(j + 1) * k];
            let v = dot(arow, brow);
            c[i * m + j] = v;
            c[j * m + i] = v;
        }
    }
}

/// Unrolled dot product (4-way accumulation to break the dependency chain).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let p = i * 4;
        s0 += a[p] * b[p];
        s1 += a[p + 1] * b[p + 1];
        s2 += a[p + 2] * b[p + 2];
        s3 += a[p + 3] * b[p + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn rand_buf(rng: &mut impl Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn gemm_nn_matches_naive_over_shapes() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        // Includes sizes straddling the block boundaries.
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 64, 9), (65, 257, 33), (8, 300, 8)] {
            let a = rand_buf(&mut rng, m * k);
            let b = rand_buf(&mut rng, k * n);
            let mut c = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c);
            let expect = naive_nn(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-10, "m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn gemm_nn_skips_zeros_correctly() {
        // Rows of A containing zeros (ReLU-style sparsity) must still be exact.
        let mut rng = Xoshiro256StarStar::seed_from_u64(12);
        let (m, k, n) = (9, 40, 7);
        let mut a = rand_buf(&mut rng, m * k);
        for v in a.iter_mut().step_by(2) {
            *v = 0.0;
        }
        let b = rand_buf(&mut rng, k * n);
        let mut c = vec![0.0; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c);
        let expect = naive_nn(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(13);
        for &(m, k, n) in &[(2, 3, 2), (19, 70, 11), (1, 128, 1)] {
            let a = rand_buf(&mut rng, m * k);
            let bt = rand_buf(&mut rng, n * k); // B stored as n×k
            // Materialize B = btᵀ for the naive reference.
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_nt(m, k, n, &a, &bt, &mut c);
            let expect = naive_nn(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn syrk_matches_naive_and_is_symmetric() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(14);
        let (m, k) = (23, 57);
        let a = rand_buf(&mut rng, m * k);
        let mut c = vec![0.0; m * m];
        syrk(m, k, &a, &mut c);
        // Reference via gemm_nt with itself.
        let mut r = vec![0.0; m * m];
        gemm_nt(m, k, m, &a, &a, &mut r);
        for (x, y) in c.iter().zip(&r) {
            assert!((x - y).abs() < 1e-10);
        }
        for i in 0..m {
            for j in 0..m {
                assert_eq!(c[i * m + j], c[j * m + i]);
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..9 {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i * 2) as f64).collect();
            let expect: f64 = (0..n).map(|i| (i * i * 2) as f64).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }
}
