//! GEMM kernels over row-major `f64` buffers.
//!
//! Three variants cover everything the crate needs:
//!
//! * [`gemm_nn`] — `C += A·B`, the panel-packed register-blocked kernel
//!   from [`super::pack`] (a 4×8 tile of independent accumulator chains
//!   fed from a thread-local packing arena; bit-identical to the classic
//!   `i-k-j` axpy loop it replaced, and allocation-free in steady state).
//! * [`gemm_nt`] — `C += A·Bᵀ` (dot-product form; no transpose
//!   materialized).
//! * [`syrk`]    — `C = A·Aᵀ` exploiting symmetry (half the FLOPs),
//!   4×4-tiled in [`super::pack`] with partition-independent per-element
//!   chains (the threaded Gram build relies on this).
//!
//! The kernels here are single-threaded: in dSSFN the *workers* are the
//! primary parallelism axis (M node threads). When `M` is smaller than
//! the thread budget the coordinator hands the leftover threads to
//! [`super::pack::syrk_mt`] via `Matrix::gram_threaded` — row-banded and
//! bit-identical to the sequential build.

pub use super::pack::{gemm_nn, syrk};

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (dot-product form; C accumulated into).
pub fn gemm_nt(m: usize, k: usize, n: usize, a: &[f64], b: &[f64], c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            crow[j] += dot(arow, brow);
        }
    }
}

/// Unrolled dot product (4-way accumulation to break the dependency chain).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let p = i * 4;
        s0 += a[p] * b[p];
        s1 += a[p + 1] * b[p + 1];
        s2 += a[p + 2] * b[p + 2];
        s3 += a[p + 3] * b[p + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Xoshiro256StarStar};

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f64], b: &[f64]) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn rand_buf(rng: &mut impl Rng, len: usize) -> Vec<f64> {
        (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
    }

    #[test]
    fn gemm_nn_matches_naive_over_shapes() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(11);
        // Includes sizes straddling the block boundaries.
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 64, 9), (65, 257, 33), (8, 300, 8)] {
            let a = rand_buf(&mut rng, m * k);
            let b = rand_buf(&mut rng, k * n);
            let mut c = vec![0.0; m * n];
            gemm_nn(m, k, n, &a, &b, &mut c);
            let expect = naive_nn(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-10, "m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn gemm_nn_skips_zeros_correctly() {
        // Rows of A containing zeros (ReLU-style sparsity) must still be exact.
        let mut rng = Xoshiro256StarStar::seed_from_u64(12);
        let (m, k, n) = (9, 40, 7);
        let mut a = rand_buf(&mut rng, m * k);
        for v in a.iter_mut().step_by(2) {
            *v = 0.0;
        }
        let b = rand_buf(&mut rng, k * n);
        let mut c = vec![0.0; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c);
        let expect = naive_nn(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&expect) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(13);
        for &(m, k, n) in &[(2, 3, 2), (19, 70, 11), (1, 128, 1)] {
            let a = rand_buf(&mut rng, m * k);
            let bt = rand_buf(&mut rng, n * k); // B stored as n×k
            // Materialize B = btᵀ for the naive reference.
            let mut b = vec![0.0; k * n];
            for j in 0..n {
                for p in 0..k {
                    b[p * n + j] = bt[j * k + p];
                }
            }
            let mut c = vec![0.0; m * n];
            gemm_nt(m, k, n, &a, &bt, &mut c);
            let expect = naive_nn(m, k, n, &a, &b);
            for (x, y) in c.iter().zip(&expect) {
                assert!((x - y).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn syrk_matches_naive_and_is_symmetric() {
        let mut rng = Xoshiro256StarStar::seed_from_u64(14);
        let (m, k) = (23, 57);
        let a = rand_buf(&mut rng, m * k);
        let mut c = vec![0.0; m * m];
        syrk(m, k, &a, &mut c);
        // Reference via gemm_nt with itself.
        let mut r = vec![0.0; m * m];
        gemm_nt(m, k, m, &a, &a, &mut r);
        for (x, y) in c.iter().zip(&r) {
            assert!((x - y).abs() < 1e-10);
        }
        for i in 0..m {
            for j in 0..m {
                assert_eq!(c[i * m + j], c[j * m + i]);
            }
        }
    }

    #[test]
    fn dot_handles_remainders() {
        for n in 0..9 {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (i * 2) as f64).collect();
            let expect: f64 = (0..n).map(|i| (i * i * 2) as f64).sum();
            assert_eq!(dot(&a, &b), expect);
        }
    }
}
