//! # dssfn — Decentralized SSFN with Centralized Equivalence
//!
//! A production-grade reproduction of *"A Low Complexity Decentralized
//! Neural Net with Centralized Equivalence using Layer-wise Learning"*
//! (Liang, Javid, Skoglund, Chatterjee; KTH 2020).
//!
//! The library trains a Self-Size-estimating Feed-forward Network (SSFN)
//! across `M` workers that each hold a private shard of the training set.
//! There is **no master node** and **no data sharing**: the only quantity
//! that crosses the (simulated) network is the per-layer output matrix
//! `O_l ∈ R^{Q×n}` plus ADMM duals, averaged by gossip over a
//! doubly-stochastic mixing matrix. The result is *exactly* the model a
//! centralized solver with all the data would produce (up to ADMM /
//! consensus tolerance) — "centralized equivalence".
//!
//! ## Architecture (three layers, Python never on the hot path)
//!
//! * **L3 (this crate)** — the decentralized training runtime: worker
//!   threads, synchronous gossip rounds, the consensus-ADMM loop,
//!   layer-wise progression, metrics, config and CLI.
//! * **L2 (`python/compile/model.py`)** — the JAX compute graph of every
//!   dSSFN step, lowered once by `make artifacts` into HLO text.
//! * **L1 (`python/compile/kernels/`)** — Pallas kernels (fused
//!   matmul+ReLU layer forward, fused Gram accumulation, fused ADMM
//!   O-update) called from the L2 graph.
//! * **Runtime (`runtime`)** — loads `artifacts/*.hlo.txt` via the PJRT
//!   CPU client (`xla` crate) and executes them from the L3 hot path. A
//!   bit-portable native `f64` path ([`linalg`]) doubles as the oracle.
//!
//! ## Quick start — the session API
//!
//! Training is a drivable state machine: build a [`session::TrainSession`]
//! (fluently, or by lowering an [`ExperimentConfig`]), then step it for
//! typed events or run it to completion:
//!
//! ```no_run
//! use dssfn::session::SessionBuilder;
//!
//! let session = SessionBuilder::new()
//!     .dataset("satimage-small")
//!     .seed(7)
//!     .build()
//!     .unwrap();
//! let (_model, report) = session.run_to_completion().unwrap();
//! println!("test accuracy = {:.2}%", 100.0 * report.test_accuracy);
//! ```
//!
//! Sessions can be observed ([`session::TrainObserver`]), budgeted
//! ([`session::StopPolicy`]: simulated seconds, communicated bytes,
//! cost plateau), checkpointed mid-layer
//! ([`coordinator::Checkpoint`]) and resumed **bit-identically**
//! ([`coordinator::resume_session`]). The dSSFN trainer, the
//! single-layer ADMM oracle and the DGD / backprop-MLP baselines all
//! implement one [`session::Algorithm`] trait, so the CLI, benches and
//! examples drive every method through the same loop.
//!
//! The communication layer is pluggable too: gossip runs behind a
//! [`network::CommFabric`] — synchronous (the paper's model),
//! semi-synchronous with bounded staleness, or lossy links — and an
//! optional [`network::AdaptiveDeltaPolicy`] throttles the consensus
//! tolerance δ while a layer's objective is plateaued
//! ([`session::SessionBuilder::comm_fabric`],
//! [`session::SessionBuilder::adaptive_delta`]).
//!
//! ## Quick start — legacy one-shot path
//!
//! The pre-session entry points remain supported (they now wrap a
//! default session and are bit-identical to the historical behaviour):
//!
//! ```no_run
//! use dssfn::config::ExperimentConfig;
//! use dssfn::coordinator::DecentralizedTrainer;
//!
//! let cfg = ExperimentConfig::named_dataset("satimage-small").unwrap();
//! let task = cfg.generate_task().unwrap();
//! let trainer = DecentralizedTrainer::from_config(&cfg).unwrap();
//! let (_model, report) = trainer.train_task(&task).unwrap();
//! println!("test accuracy = {:.2}%", 100.0 * report.test_accuracy);
//! ```

// Dense-kernel code is index-loop-heavy by nature; iterator rewrites of
// the blocked GEMM/SYRK loops obscure the access pattern LLVM needs to
// see for vectorization without changing the generated code.
#![allow(clippy::needless_range_loop)]

pub mod admm;
pub mod baselines;
pub mod clidoc;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod network;
pub mod node;
pub mod runtime;
pub mod session;
pub mod simulator;
pub mod ssfn;
pub mod testing;
pub mod transport;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::{
    resume_session, resume_session_with_policy, Checkpoint, DecentralizedTrainer,
};
pub use session::{
    SessionBuilder, StepEvent, StopPolicy, StopReason, TrainObserver, TrainSession,
};
pub use ssfn::CentralizedTrainer;

/// Crate-wide error type.
///
/// The `Display`/`Error` impls are hand-written (the build image is fully
/// offline, so the crate carries no `thiserror` dependency).
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch in a linear-algebra operation.
    Shape(String),
    /// A matrix factorization failed (e.g. non-SPD input to Cholesky).
    Numerical(String),
    /// Invalid configuration value.
    Config(String),
    /// Problem with the communication-network model.
    Network(String),
    /// Checkpoint serialization/restore failure (corrupt bytes,
    /// version mismatch, task/config fingerprint mismatch).
    Checkpoint(String),
    /// PJRT runtime failure (artifact missing, compile/execute error).
    Runtime(String),
    /// Dataset construction / sharding failure.
    Data(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Network(m) => write!(f, "network error: {m}"),
            Error::Checkpoint(m) => write!(f, "checkpoint error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Data(m) => write!(f, "data error: {m}"),
            // Transparent: forward the io error's own message.
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
