//! Decentralized gradient descent on the layer-wise convex objective.
//!
//! Solves the same problem as the ADMM path —
//! `min_O Σ_m ‖T_m − O Y_m‖²_F  s.t. ‖O‖²_F ≤ ε` —
//! by projected consensus gradient descent (paper eq. 13): every node
//! computes its local gradient, the gradients are gossip-averaged, all
//! nodes take the same step and project. Centralized-equivalent like
//! dSSFN, but each iteration ships a full gradient matrix and `I ≫ K`,
//! which is exactly the communication gap eq. (16) quantifies.

use crate::admm::LayerLocalSolver;
use crate::linalg::Matrix;
use crate::metrics::{LayerRecord, TrainReport};
use crate::network::{CommFabric, GossipEngine};
use crate::session::{
    Algorithm, AlgorithmOutput, SessionProgress, StepEvent, StopReason, TrainedModel,
};
use crate::{Error, Result};

/// Parameters for the DGD solve.
#[derive(Debug, Clone, Copy)]
pub struct DgdParams {
    /// Step size `κ`.
    pub step: f64,
    /// Iterations `I`.
    pub iterations: usize,
    /// Frobenius ball radius `ε`.
    pub eps: f64,
    /// Gossip contraction per averaging (when gossiping).
    pub delta: f64,
}

impl DgdParams {
    /// Validate ranges.
    pub fn validate(&self) -> Result<()> {
        if self.step <= 0.0 {
            return Err(Error::Config("DGD step must be > 0".into()));
        }
        if self.iterations == 0 {
            return Err(Error::Config("DGD needs >= 1 iteration".into()));
        }
        if self.eps <= 0.0 {
            return Err(Error::Config("DGD eps must be > 0".into()));
        }
        Ok(())
    }
}

/// Result of a DGD solve.
#[derive(Debug)]
pub struct DgdSolution {
    /// The consensus iterate (identical on all nodes by construction).
    pub o: Matrix,
    /// Global objective after each iteration.
    pub cost_curve: Vec<f64>,
    /// Total gossip rounds.
    pub gossip_rounds: usize,
}

/// Per-node gradient context: `∇_O ‖T_m − O Y_m‖² = 2(O·YYᵀ − TYᵀ)`.
/// Reuses [`LayerLocalSolver`]'s cached Grams (built with a huge `μ` so
/// the ridge term is negligible; only `gram0`/`tyt`/`cost` are used).
pub struct DgdNode {
    solver: LayerLocalSolver,
    gram0: Matrix,
}

impl DgdNode {
    /// Build from local features and targets.
    pub fn new(y: &Matrix, t: &Matrix) -> Result<Self> {
        // μ large ⇒ ridge 1/μ ≈ 0; we only use the Gram caches.
        let solver = LayerLocalSolver::new(y, t, 1e12)?;
        let gram0 = y.gram();
        Ok(Self { solver, gram0 })
    }

    /// Local gradient at `o`.
    pub fn gradient(&self, o: &Matrix) -> Result<Matrix> {
        let mut g = o.matmul(&self.gram0)?;
        g.axpy(-1.0, self.solver.tyt())?;
        g.scale_inplace(2.0);
        Ok(g)
    }

    /// Local cost at `o`.
    pub fn cost(&self, o: &Matrix) -> Result<f64> {
        self.solver.cost(o)
    }
}

/// Decentralized projected gradient descent as a step-wise
/// [`Algorithm`]: each [`Algorithm::advance`] performs one full
/// gradient-gossip-step iteration — the exact operation sequence of the
/// legacy `solve_dgd` loop, which is now a wrapper over this machine.
/// Gradient averages run through a [`CommFabric`], so the baseline
/// exercises the same sync / semi-sync / lossy schedules as the dSSFN
/// trainer.
pub struct DgdAlgorithm<'a> {
    nodes: &'a [DgdNode],
    params: DgdParams,
    fabric: Option<&'a dyn CommFabric>,
    o: Matrix,
    grads: Vec<Matrix>,
    cost_curve: Vec<f64>,
    gossip_rounds: usize,
    k: usize,
    done: bool,
    finalized: bool,
    stop_reason: Option<StopReason>,
}

impl<'a> DgdAlgorithm<'a> {
    /// Validate and set up a solve for a `q×n` output across the nodes.
    /// When `fabric` is `Some`, gradient averages run over it (and are
    /// charged to its engine's ledger); otherwise the exact average is
    /// used.
    pub fn new(
        nodes: &'a [DgdNode],
        q: usize,
        n: usize,
        params: &DgdParams,
        fabric: Option<&'a dyn CommFabric>,
    ) -> Result<Self> {
        params.validate()?;
        if nodes.is_empty() {
            return Err(Error::Config("no nodes".into()));
        }
        let m = nodes.len();
        Ok(Self {
            nodes,
            params: *params,
            fabric,
            o: Matrix::zeros(q, n),
            grads: (0..m).map(|_| Matrix::zeros(q, n)).collect(),
            cost_curve: Vec::with_capacity(params.iterations),
            gossip_rounds: 0,
            k: 0,
            done: false,
            finalized: false,
            stop_reason: None,
        })
    }

    /// Consume the finished solve into the legacy solution struct.
    pub fn into_solution(self) -> Result<DgdSolution> {
        if !self.done {
            return Err(Error::Config("DGD solve not finished".into()));
        }
        Ok(DgdSolution {
            o: self.o,
            cost_curve: self.cost_curve,
            gossip_rounds: self.gossip_rounds,
        })
    }
}

impl Algorithm for DgdAlgorithm<'_> {
    fn describe(&self) -> String {
        format!(
            "dgd({} nodes, {})",
            self.nodes.len(),
            match self.fabric {
                Some(fab) => format!("gossip {}", fab.describe()),
                None => "exact-avg".to_string(),
            }
        )
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn advance(&mut self, events: &mut Vec<StepEvent>) -> Result<()> {
        if self.done {
            return Err(Error::Config("DGD solve already finished".into()));
        }
        let k = self.k;
        for (g, node) in self.grads.iter_mut().zip(self.nodes) {
            let ng = node.gradient(&self.o)?;
            g.copy_from(&ng)?;
        }
        let mut gossip_event: Option<(usize, u64)> = None;
        let avg = match self.fabric {
            Some(fab) => {
                let (rounds, bytes) = fab.average(&mut self.grads, self.params.delta)?;
                self.gossip_rounds += rounds;
                gossip_event = Some((rounds, bytes));
                self.grads[0].clone()
            }
            None => GossipEngine::exact_average(&self.grads)?,
        };
        self.o.axpy(-self.params.step, &avg)?;
        self.o.project_frobenius(self.params.eps);
        let mut c = 0.0;
        for node in self.nodes {
            c += node.cost(&self.o)?;
        }
        self.cost_curve.push(c);

        if let Some((rounds, bytes)) = gossip_event {
            events.push(StepEvent::GossipRound { layer: 0, iteration: k, rounds, bytes });
        }
        events.push(StepEvent::AdmmIteration {
            layer: 0,
            iteration: k,
            cost: Some(c),
            consensus_gap: 0.0,
        });
        self.k += 1;
        if self.k >= self.params.iterations || self.stop_reason.is_some() {
            self.done = true;
            events.push(StepEvent::Finished {
                reason: self.stop_reason.unwrap_or(StopReason::Completed),
            });
        }
        Ok(())
    }

    fn finalize(&mut self) -> Result<AlgorithmOutput> {
        if !self.done {
            return Err(Error::Config("finalize before the solve finished".into()));
        }
        if self.finalized {
            return Err(Error::Config("DGD solve already finalized".into()));
        }
        self.finalized = true;
        let mut report = TrainReport {
            mode: self.describe(),
            ..Default::default()
        };
        report.layers.push(LayerRecord {
            layer: 0,
            cost_curve: self.cost_curve.clone(),
            gossip_rounds: self.gossip_rounds,
            ..Default::default()
        });
        if let Some(fab) = self.fabric {
            report.comm_total = fab.engine().ledger().snapshot();
            report.simulated_comm_secs = fab.engine().simulated_seconds();
        }
        Ok(AlgorithmOutput {
            model: TrainedModel::Output(self.o.clone()),
            report,
        })
    }

    fn progress(&self) -> SessionProgress {
        match self.fabric {
            Some(fab) => SessionProgress {
                comm_bytes: fab.engine().ledger().snapshot().bytes,
                simulated_secs: fab.engine().simulated_seconds(),
            },
            None => SessionProgress::default(),
        }
    }

    fn request_stop(&mut self, reason: StopReason) {
        if self.stop_reason.is_none() && !self.done {
            self.stop_reason = Some(reason);
        }
    }
}

/// Run decentralized projected gradient descent. When `fabric` is
/// `Some`, gradient averages run over it (and are charged to its
/// engine's ledger); otherwise the exact average is used. Implemented as
/// a loop over [`DgdAlgorithm`] — the one-shot call and the
/// session-driven path are the same computation.
pub fn solve_dgd(
    nodes: &[DgdNode],
    q: usize,
    n: usize,
    params: &DgdParams,
    fabric: Option<&dyn CommFabric>,
) -> Result<DgdSolution> {
    let mut alg = DgdAlgorithm::new(nodes, q, n, params, fabric)?;
    crate::session::drive_to_completion(&mut alg)?;
    alg.into_solution()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admm::{solve_centralized, AdmmParams};
    use crate::network::{CommLedger, LatencyModel, MixingMatrix, Topology, WeightRule};
    use crate::util::{Rng, Xoshiro256StarStar};
    use std::sync::Arc;

    fn rand_mat(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.uniform(-1.0, 1.0))
    }

    fn split_nodes(y: &Matrix, t: &Matrix, m: usize) -> Vec<DgdNode> {
        let j = y.cols();
        let per = j / m;
        (0..m)
            .map(|i| {
                let c0 = i * per;
                let c1 = if i == m - 1 { j } else { (i + 1) * per };
                DgdNode::new(&y.col_block(c0, c1).unwrap(), &t.col_block(c0, c1).unwrap())
                    .unwrap()
            })
            .collect()
    }

    #[test]
    fn gradient_is_zero_at_least_squares_solution() {
        let y = rand_mat(5, 30, 1);
        let t = rand_mat(2, 30, 2);
        let node = DgdNode::new(&y, &t).unwrap();
        let ls = y
            .gram()
            .cholesky()
            .unwrap()
            .solve_xa(&t.matmul_transb(&y).unwrap())
            .unwrap();
        let g = node.gradient(&ls).unwrap();
        assert!(g.frobenius_norm() < 1e-7);
    }

    #[test]
    fn dgd_converges_to_admm_solution() {
        // Both solve the same convex problem ⇒ same optimum.
        let y = rand_mat(6, 60, 3);
        let t = rand_mat(2, 60, 4);
        let eps = 4.0;
        let admm = solve_centralized(
            &y,
            &t,
            &AdmmParams { mu: 1.0, eps, iterations: 500 },
        )
        .unwrap()
        .0;
        let nodes = split_nodes(&y, &t, 3);
        // Lipschitz-safe step: 1/(2·λmax(YYᵀ)) bounded by trace.
        let step = 0.5 / y.gram().as_slice().iter().sum::<f64>().abs();
        let sol = solve_dgd(
            &nodes,
            2,
            6,
            &DgdParams { step, iterations: 4000, eps, delta: 1e-9 },
            None,
        )
        .unwrap();
        let diff = sol.o.max_abs_diff(&admm);
        assert!(diff < 1e-3, "DGD vs ADMM diff {diff}");
        // Monotone-ish decrease overall.
        assert!(sol.cost_curve.last().unwrap() < sol.cost_curve.first().unwrap());
    }

    #[test]
    fn gossip_dgd_charges_much_more_traffic_than_admm_for_same_accuracy() {
        // The eq.(16) mechanism in miniature: same topology, same target
        // objective gap, DGD needs far more scalars on the wire.
        use crate::network::SynchronousFabric;
        let y = rand_mat(6, 48, 5);
        let t = rand_mat(2, 48, 6);
        let eps = 4.0;
        let m = 6;
        let topo = Topology::Circular { nodes: m, degree: 2 };
        let mk_engine = || {
            GossipEngine::new(
                MixingMatrix::build(&topo, WeightRule::EqualNeighbor).unwrap(),
                Arc::new(CommLedger::new()),
                LatencyModel::default(),
            )
        };

        // ADMM side.
        let solvers: Vec<crate::admm::LayerLocalSolver> = {
            let per = 48 / m;
            (0..m)
                .map(|i| {
                    crate::admm::LayerLocalSolver::new(
                        &y.col_block(i * per, (i + 1) * per).unwrap(),
                        &t.col_block(i * per, (i + 1) * per).unwrap(),
                        1.0,
                    )
                    .unwrap()
                })
                .collect()
        };
        let admm_engine = mk_engine();
        let admm_sol = crate::admm::solve_decentralized(
            &solvers,
            2,
            6,
            &crate::admm::AdmmParams { mu: 1.0, eps, iterations: 60 },
            &crate::admm::Consensus::Gossip { engine: &admm_engine, delta: 1e-8 },
        )
        .unwrap();
        let admm_bytes = admm_engine.ledger().snapshot().bytes;
        let admm_cost = *admm_sol.cost_curve.last().unwrap();

        // DGD side: run until it reaches the same objective value.
        let nodes = split_nodes(&y, &t, m);
        let step = 0.5 / y.gram().as_slice().iter().sum::<f64>().abs();
        let dgd_fabric = SynchronousFabric::new(mk_engine());
        let sol = solve_dgd(
            &nodes,
            2,
            6,
            &DgdParams { step, iterations: 3000, eps, delta: 1e-8 },
            Some(&dgd_fabric),
        )
        .unwrap();
        let reached = sol
            .cost_curve
            .iter()
            .position(|&c| c <= admm_cost * 1.001)
            .unwrap_or(sol.cost_curve.len());
        let dgd_bytes = dgd_fabric.engine().ledger().snapshot().bytes * reached as u64
            / sol.cost_curve.len() as u64;
        assert!(
            dgd_bytes > admm_bytes,
            "DGD bytes {dgd_bytes} should exceed ADMM bytes {admm_bytes}"
        );
    }

    #[test]
    fn session_driven_dgd_matches_direct_call() {
        // DgdAlgorithm through a TrainSession is the same computation as
        // the one-shot solve_dgd.
        let y = rand_mat(5, 30, 9);
        let t = rand_mat(2, 30, 10);
        let nodes = split_nodes(&y, &t, 3);
        let step = 0.5 / y.gram().as_slice().iter().sum::<f64>().abs();
        let params = DgdParams { step, iterations: 50, eps: 4.0, delta: 1e-9 };
        let direct = solve_dgd(&nodes, 2, 5, &params, None).unwrap();

        let alg = DgdAlgorithm::new(&nodes, 2, 5, &params, None).unwrap();
        let session = crate::session::TrainSession::from_algorithm(Box::new(alg));
        let (model, report) = session.run_to_completion().unwrap();
        let o = model.into_output().unwrap();
        assert_eq!(o.max_abs_diff(&direct.o), 0.0);
        assert_eq!(report.layers[0].cost_curve, direct.cost_curve);
        assert!(report.mode.starts_with("dgd("));
    }

    #[test]
    fn dgd_over_semisync_fabric_still_converges() {
        // The baseline exercises the same pluggable schedules as the
        // trainer: a staleness-2 fabric perturbs each gradient average
        // slightly but projected GD still reaches the ADMM optimum's
        // neighbourhood.
        use crate::network::SemiSyncFabric;
        let y = rand_mat(6, 60, 31);
        let t = rand_mat(2, 60, 32);
        let eps = 4.0;
        let admm = solve_centralized(
            &y,
            &t,
            &AdmmParams { mu: 1.0, eps, iterations: 500 },
        )
        .unwrap()
        .0;
        let m = 4;
        let engine = GossipEngine::new(
            MixingMatrix::build(
                &Topology::Circular { nodes: m, degree: 2 },
                WeightRule::EqualNeighbor,
            )
            .unwrap(),
            Arc::new(CommLedger::new()),
            LatencyModel::default(),
        );
        let fabric = SemiSyncFabric::new(engine, 2, 5);
        let nodes = split_nodes(&y, &t, m);
        let step = 0.5 / y.gram().as_slice().iter().sum::<f64>().abs();
        let sol = solve_dgd(
            &nodes,
            2,
            6,
            &DgdParams { step, iterations: 4000, eps, delta: 1e-9 },
            Some(&fabric),
        )
        .unwrap();
        let diff = sol.o.max_abs_diff(&admm);
        assert!(diff < 2e-2, "semisync DGD vs ADMM diff {diff}");
        assert!(sol.gossip_rounds > 0);
    }

    #[test]
    fn param_validation() {
        assert!(DgdParams { step: 0.0, iterations: 1, eps: 1.0, delta: 1e-9 }
            .validate()
            .is_err());
        assert!(DgdParams { step: 0.1, iterations: 0, eps: 1.0, delta: 1e-9 }
            .validate()
            .is_err());
        assert!(DgdParams { step: 0.1, iterations: 1, eps: 0.0, delta: 1e-9 }
            .validate()
            .is_err());
        let y = rand_mat(3, 10, 7);
        let t = rand_mat(2, 10, 8);
        let _ = DgdNode::new(&y, &t).unwrap();
        assert!(solve_dgd(
            &[],
            2,
            3,
            &DgdParams { step: 0.1, iterations: 1, eps: 1.0, delta: 1e-9 },
            None
        )
        .is_err());
    }
}
