//! Baseline algorithms the paper compares against (§II-E).
//!
//! * [`dgd`] — **decentralized gradient descent** on the same layer-wise
//!   convex objective, with gossip-averaged gradients (eq. 13). It reaches
//!   the same solution but needs `I ≫ K` iterations, each with a gossip
//!   averaging of the *full weight gradient* — the communication-load
//!   comparison of eq. (14)–(16) is measured against it.
//! * [`mlp_sgd`] — a conventional backprop MLP trained with decentralized
//!   SGD (gradient gossip every step). This is the "general
//!   gradient-based method" of the paper's complexity argument: the
//!   exchanged object is the whole `n_l × n_{l-1}` weight stack, not a
//!   `Q × n` output matrix.

pub mod dgd;
pub mod mlp_sgd;

pub use dgd::{DgdAlgorithm, DgdParams, DgdSolution};
pub use mlp_sgd::{MlpModel, MlpSgdAlgorithm, MlpSgdParams, MlpSgdTrainer};
