//! Decentralized backprop MLP baseline.
//!
//! A conventional ReLU MLP of the same depth/width as the SSFN, trained
//! with full-batch decentralized gradient descent: each step every node
//! backpropagates on its shard and the *entire weight-stack gradient* is
//! gossip-averaged (paper eq. 13). This is the "general gradient-based
//! method" whose per-iteration traffic is `Σ_l n_l·n_{l-1}` scalars —
//! versus dSSFN's `Q·n` — the numerator of eq. (16).

use crate::data::{ClassificationTask, Dataset};
use crate::linalg::{accuracy_from_predictions, Matrix};
use crate::metrics::{error_db, LayerRecord, TrainReport};
use crate::network::{CommFabric, GossipEngine};
use crate::session::{
    Algorithm, AlgorithmOutput, SessionProgress, StepEvent, StopReason, TrainedModel,
};
use crate::util::{Rng, Xoshiro256StarStar};
use crate::{Error, Result};

/// MLP + decentralized SGD parameters.
#[derive(Debug, Clone, Copy)]
pub struct MlpSgdParams {
    /// Hidden width per layer.
    pub hidden: usize,
    /// Hidden layer count.
    pub layers: usize,
    /// Step size.
    pub step: f64,
    /// Full-batch iterations `I`.
    pub iterations: usize,
    /// Gossip contraction per gradient averaging.
    pub delta: f64,
    /// Init scale seed.
    pub seed: u64,
}

/// Trains the baseline MLP across shards with gossiped gradients.
pub struct MlpSgdTrainer {
    params: MlpSgdParams,
}

/// The trained MLP (weights only; biases omitted as in the SSFN).
pub struct MlpModel {
    /// `W_1..W_L` then output `O` last.
    pub weights: Vec<Matrix>,
}

impl MlpModel {
    /// Forward pass returning scores `Q×J`.
    pub fn scores(&self, x: &Matrix) -> Result<Matrix> {
        let (hidden, out) = self.weights.split_at(self.weights.len() - 1);
        let mut y = x.clone();
        for w in hidden {
            y = w.matmul(&y)?;
            y.relu_inplace();
        }
        out[0].matmul(&y)
    }

    /// Accuracy on a dataset.
    pub fn accuracy(&self, d: &Dataset) -> Result<f64> {
        accuracy_from_predictions(&self.scores(&d.x)?, &d.labels)
    }
}

impl MlpSgdTrainer {
    /// Create a trainer.
    pub fn new(params: MlpSgdParams) -> Result<Self> {
        if params.hidden == 0 || params.layers == 0 {
            return Err(Error::Config("MLP needs hidden>0, layers>0".into()));
        }
        if params.step <= 0.0 || params.iterations == 0 {
            return Err(Error::Config("MLP needs step>0, iterations>0".into()));
        }
        Ok(Self { params })
    }

    fn init_weights(&self, p: usize, q: usize) -> Vec<Matrix> {
        let mut rng = Xoshiro256StarStar::seed_from_u64(self.params.seed);
        let mut ws = Vec::with_capacity(self.params.layers + 1);
        let mut fan_in = p;
        for _ in 0..self.params.layers {
            let bound = (3.0 / fan_in as f64).sqrt() * 0.7; // conservative He-ish
            ws.push(Matrix::from_fn(self.params.hidden, fan_in, |_, _| {
                rng.uniform(-bound, bound)
            }));
            fan_in = self.params.hidden;
        }
        let bound = (3.0 / fan_in as f64).sqrt();
        ws.push(Matrix::from_fn(q, fan_in, |_, _| rng.uniform(-bound, bound)));
        ws
    }

    /// Local full-batch gradient of `‖T − f(X)‖²_F` w.r.t. every weight.
    fn gradients(ws: &[Matrix], x: &Matrix, t: &Matrix) -> Result<Vec<Matrix>> {
        let l = ws.len();
        // Forward, caching pre/post activations.
        let mut acts: Vec<Matrix> = Vec::with_capacity(l); // post-ReLU (inputs to each weight)
        acts.push(x.clone());
        let mut pre: Vec<Matrix> = Vec::with_capacity(l - 1);
        let mut y = x.clone();
        for w in &ws[..l - 1] {
            let z = w.matmul(&y)?;
            pre.push(z.clone());
            let mut a = z;
            a.relu_inplace();
            acts.push(a.clone());
            y = a;
        }
        let scores = ws[l - 1].matmul(&y)?;
        // Backward.
        let mut grads: Vec<Matrix> = vec![Matrix::zeros(1, 1); l];
        let mut delta = scores.sub(t)?; // d/dscores of ½‖·‖² scaled: use 2× at end
        delta.scale_inplace(2.0);
        grads[l - 1] = delta.matmul_transb(&acts[l - 1])?;
        for li in (0..l - 1).rev() {
            // delta = (W_{li+1}ᵀ delta_{li+1}) ⊙ relu'(pre_li)
            let wt = ws[li + 1].transpose();
            let mut d = wt.matmul(&delta)?;
            let zpre = &pre[li];
            for (dv, zv) in d.as_mut_slice().iter_mut().zip(zpre.as_slice()) {
                if *zv <= 0.0 {
                    *dv = 0.0;
                }
            }
            grads[li] = d.matmul_transb(&acts[li])?;
            delta = d;
        }
        Ok(grads)
    }

    /// Train across `shards`; gradients are averaged over the
    /// [`CommFabric`] when given (so the baseline sweeps the same sync /
    /// semi-sync / lossy schedules as the dSSFN trainer and DGD),
    /// exactly averaged otherwise. Returns the model and a report (cost
    /// curve = global objective per iteration). Implemented as a loop
    /// over [`MlpSgdAlgorithm`] — the one-shot call and the
    /// session-driven path are the same computation.
    pub fn train(
        &self,
        task: &ClassificationTask,
        shards: &[Dataset],
        fabric: Option<&dyn CommFabric>,
    ) -> Result<(MlpModel, TrainReport)> {
        let mut alg = MlpSgdAlgorithm::new(self.params, task, shards, fabric)?;
        crate::session::drive_to_completion(&mut alg)?;
        let out = alg.finalize()?;
        Ok((out.model.into_mlp()?, out.report))
    }

    /// Scalars exchanged per gradient averaging (eq. 14's `n_l·n_{l-1}`
    /// summed over layers) — used by the comm-load bench.
    pub fn scalars_per_exchange(&self, p: usize, q: usize) -> usize {
        let mut total = self.params.hidden * p;
        total += (self.params.layers - 1) * self.params.hidden * self.params.hidden;
        total += q * self.params.hidden;
        total
    }
}

/// The backprop-MLP baseline as a step-wise [`Algorithm`]: each
/// [`Algorithm::advance`] performs one full-batch decentralized SGD
/// iteration (per-shard backprop, per-layer gradient gossip, weight
/// step, objective eval) — the exact operation sequence of the legacy
/// `MlpSgdTrainer::train` loop, which is now a wrapper over this type.
/// Gradient averages run through a [`CommFabric`], so baseline-table
/// sweeps exercise the same pluggable schedules as the trainer.
pub struct MlpSgdAlgorithm<'a> {
    params: MlpSgdParams,
    task: &'a ClassificationTask,
    shards: &'a [Dataset],
    fabric: Option<&'a dyn CommFabric>,
    ws: Vec<Matrix>,
    curve: Vec<f64>,
    gossip_rounds: usize,
    scale: f64,
    k: usize,
    done: bool,
    finalized: bool,
    stop_reason: Option<StopReason>,
}

impl<'a> MlpSgdAlgorithm<'a> {
    /// Validate the parameters and initialize the weight stack.
    pub fn new(
        params: MlpSgdParams,
        task: &'a ClassificationTask,
        shards: &'a [Dataset],
        fabric: Option<&'a dyn CommFabric>,
    ) -> Result<Self> {
        let trainer = MlpSgdTrainer::new(params)?;
        if shards.is_empty() {
            return Err(Error::Config("no shards".into()));
        }
        let ws = trainer.init_weights(task.input_dim(), task.num_classes());
        Ok(Self {
            params,
            task,
            shards,
            fabric,
            ws,
            curve: Vec::with_capacity(params.iterations),
            gossip_rounds: 0,
            scale: 1.0 / task.train.num_samples() as f64,
            k: 0,
            done: false,
            finalized: false,
            stop_reason: None,
        })
    }
}

impl Algorithm for MlpSgdAlgorithm<'_> {
    fn describe(&self) -> String {
        match self.fabric {
            Some(fab) => format!(
                "mlp-sgd({} layers, gossip {})",
                self.params.layers,
                fab.describe()
            ),
            None => format!("mlp-sgd({} layers)", self.params.layers),
        }
    }

    fn is_done(&self) -> bool {
        self.done
    }

    fn advance(&mut self, events: &mut Vec<StepEvent>) -> Result<()> {
        if self.done {
            return Err(Error::Config("MLP training already finished".into()));
        }
        let k = self.k;
        // Per-node gradients (layer-major for the averaging step).
        let mut per_layer: Vec<Vec<Matrix>> =
            vec![Vec::with_capacity(self.shards.len()); self.ws.len()];
        for sh in self.shards {
            let gs = MlpSgdTrainer::gradients(&self.ws, &sh.x, &sh.t)?;
            for (bucket, g) in per_layer.iter_mut().zip(gs) {
                bucket.push(g);
            }
        }
        // Average each layer's gradient across nodes; one aggregated
        // gossip event covers all per-layer averagings of the iteration.
        let mut iter_rounds = 0usize;
        let mut iter_bytes = 0u64;
        for (li, bucket) in per_layer.iter_mut().enumerate() {
            let avg = match self.fabric {
                Some(fab) => {
                    let (rounds, bytes) = fab.average(bucket, self.params.delta)?;
                    self.gossip_rounds += rounds;
                    iter_rounds += rounds;
                    iter_bytes += bytes;
                    bucket[0].clone()
                }
                None => GossipEngine::exact_average(bucket)?,
            };
            // Gradient sum = M × average (the objective is a sum).
            self.ws[li].axpy(-self.params.step * self.scale * self.shards.len() as f64, &avg)?;
        }
        // Objective.
        let model = MlpModel { weights: self.ws.clone() };
        let mut cost = 0.0;
        for sh in self.shards {
            cost += sh.t.sub(&model.scores(&sh.x)?)?.frobenius_norm_sq();
        }
        self.curve.push(cost);

        if self.fabric.is_some() {
            events.push(StepEvent::GossipRound {
                layer: 0,
                iteration: k,
                rounds: iter_rounds,
                bytes: iter_bytes,
            });
        }
        events.push(StepEvent::AdmmIteration {
            layer: 0,
            iteration: k,
            cost: Some(cost),
            consensus_gap: 0.0,
        });
        self.k += 1;
        if self.k >= self.params.iterations || self.stop_reason.is_some() {
            self.done = true;
            events.push(StepEvent::Finished {
                reason: self.stop_reason.unwrap_or(StopReason::Completed),
            });
        }
        Ok(())
    }

    fn finalize(&mut self) -> Result<AlgorithmOutput> {
        if !self.done {
            return Err(Error::Config("finalize before training finished".into()));
        }
        if self.finalized {
            return Err(Error::Config("MLP training already finalized".into()));
        }
        self.finalized = true;
        let model = MlpModel { weights: self.ws.clone() };
        let task = self.task;
        let mut report = TrainReport {
            dataset: task.name.clone(),
            mode: self.describe(),
            train_accuracy: model.accuracy(&task.train)?,
            test_accuracy: model.accuracy(&task.test)?,
            ..Default::default()
        };
        report.train_error_db = error_db(
            task.train
                .t
                .sub(&model.scores(&task.train.x)?)?
                .frobenius_norm_sq(),
            task.train.t.frobenius_norm_sq(),
        );
        report.layers.push(LayerRecord {
            layer: 0,
            cost_curve: self.curve.clone(),
            gossip_rounds: self.gossip_rounds,
            ..Default::default()
        });
        Ok(AlgorithmOutput {
            model: TrainedModel::Mlp(model),
            report,
        })
    }

    fn progress(&self) -> SessionProgress {
        match self.fabric {
            Some(fab) => SessionProgress {
                comm_bytes: fab.engine().ledger().snapshot().bytes,
                simulated_secs: fab.engine().simulated_seconds(),
            },
            None => SessionProgress::default(),
        }
    }

    fn request_stop(&mut self, reason: StopReason) {
        if self.stop_reason.is_none() && !self.done {
            self.stop_reason = Some(reason);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard_uniform, SynthClassification};

    fn toy_task() -> ClassificationTask {
        let mut s = SynthClassification::with_shape("toy", 6, 3, 90, 45);
        s.class_sep = 3.0;
        s.noise = 0.5;
        s.generate().unwrap()
    }

    fn params(iters: usize) -> MlpSgdParams {
        MlpSgdParams {
            hidden: 24,
            layers: 2,
            step: 0.05,
            iterations: iters,
            delta: 1e-9,
            seed: 3,
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        let task = toy_task();
        let tr = MlpSgdTrainer::new(params(1)).unwrap();
        let ws = tr.init_weights(6, 3);
        let x = task.train.x.col_block(0, 10).unwrap();
        let t = task.train.t.col_block(0, 10).unwrap();
        let grads = MlpSgdTrainer::gradients(&ws, &x, &t).unwrap();
        let cost = |ws: &[Matrix]| -> f64 {
            let m = MlpModel { weights: ws.to_vec() };
            t.sub(&m.scores(&x).unwrap()).unwrap().frobenius_norm_sq()
        };
        let h = 1e-6;
        for li in 0..ws.len() {
            for &(r, c) in &[(0usize, 0usize), (1, 2)] {
                let mut wp = ws.clone();
                let v = wp[li].get(r, c);
                wp[li].set(r, c, v + h);
                let mut wm = ws.clone();
                let v = wm[li].get(r, c);
                wm[li].set(r, c, v - h);
                let fd = (cost(&wp) - cost(&wm)) / (2.0 * h);
                let an = grads[li].get(r, c);
                assert!(
                    (fd - an).abs() < 1e-3 * (1.0 + fd.abs()),
                    "layer {li} ({r},{c}): fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn training_decreases_cost_and_learns() {
        let task = toy_task();
        let shards = shard_uniform(&task.train, 3).unwrap();
        let tr = MlpSgdTrainer::new(params(300)).unwrap();
        let (model, report) = tr.train(&task, &shards, None).unwrap();
        let curve = &report.layers[0].cost_curve;
        assert!(curve.last().unwrap() < &(curve.first().unwrap() * 0.5));
        assert!(report.train_accuracy > 0.7, "acc {}", report.train_accuracy);
        assert!(model.accuracy(&task.test).unwrap() > 0.5);
    }

    #[test]
    fn session_driven_mlp_matches_direct_train() {
        // MlpSgdAlgorithm through a TrainSession is the same computation
        // as the one-shot MlpSgdTrainer::train.
        let task = toy_task();
        let shards = shard_uniform(&task.train, 3).unwrap();
        let tr = MlpSgdTrainer::new(params(40)).unwrap();
        let (direct_model, direct_report) = tr.train(&task, &shards, None).unwrap();

        let alg = MlpSgdAlgorithm::new(params(40), &task, &shards, None).unwrap();
        let session = crate::session::TrainSession::from_algorithm(Box::new(alg));
        let (model, report) = session.run_to_completion().unwrap();
        let model = model.into_mlp().unwrap();
        for (a, b) in model.weights.iter().zip(&direct_model.weights) {
            assert_eq!(a.max_abs_diff(b), 0.0);
        }
        assert_eq!(report.layers[0].cost_curve, direct_report.layers[0].cost_curve);
        assert_eq!(report.mode, "mlp-sgd(2 layers)");
    }

    #[test]
    fn mlp_trains_over_sync_and_semisync_fabrics() {
        use crate::network::{
            CommLedger, LatencyModel, MixingMatrix, SemiSyncFabric, SynchronousFabric,
            Topology, WeightRule,
        };
        use std::sync::Arc;
        let task = toy_task();
        // A true ring (6 nodes, degree 1): B(δ) is large enough that the
        // semi-sync flush tail amortizes and the relaxed clock wins.
        let shards = shard_uniform(&task.train, 6).unwrap();
        let mk_engine = || {
            GossipEngine::new(
                MixingMatrix::build(
                    &Topology::Circular { nodes: 6, degree: 1 },
                    WeightRule::EqualNeighbor,
                )
                .unwrap(),
                Arc::new(CommLedger::new()),
                LatencyModel::default(),
            )
        };
        let tr = MlpSgdTrainer::new(params(300)).unwrap();
        // Synchronous fabric: the baseline charges real traffic and
        // still learns.
        let sync_fab = SynchronousFabric::new(mk_engine());
        let (_, sync_report) = tr.train(&task, &shards, Some(&sync_fab)).unwrap();
        assert!(sync_report.mode.contains("gossip sync"), "{}", sync_report.mode);
        assert!(sync_fab.engine().ledger().snapshot().bytes > 0);
        assert!(sync_report.layers[0].gossip_rounds > 0);
        // Semi-sync fabric: same sweep surface as the trainer and DGD —
        // this used to run silently synchronous through the bare
        // GossipEngine plumbing.
        let semi_fab = SemiSyncFabric::new(mk_engine(), 2, 7);
        let (_, semi_report) = tr.train(&task, &shards, Some(&semi_fab)).unwrap();
        assert!(semi_report.mode.contains("semisync(s=2)"), "{}", semi_report.mode);
        assert!(
            semi_fab.engine().ledger().snapshot().rounds
                > sync_fab.engine().ledger().snapshot().rounds,
            "staleness flush rounds missing"
        );
        assert!(
            semi_fab.engine().simulated_seconds()
                < sync_fab.engine().simulated_seconds(),
            "relaxed barrier should beat the synchronous clock"
        );
        // Both schedules learn the task (the objective is nonconvex, so
        // the two trajectories need not land on the same minimum — the
        // claim is that staleness does not break training).
        let semi_curve = &semi_report.layers[0].cost_curve;
        assert!(semi_curve.last().unwrap() < &(semi_curve.first().unwrap() * 0.5));
        assert!(
            semi_report.train_accuracy > 0.7,
            "semisync MLP failed to learn: acc {}",
            semi_report.train_accuracy
        );
        assert!(sync_report.train_accuracy > 0.7);
    }

    #[test]
    fn scalars_per_exchange_formula() {
        let tr = MlpSgdTrainer::new(params(1)).unwrap();
        // p=6,q=3,hidden=24,layers=2: 24·6 + 1·24·24 + 3·24 = 144+576+72
        assert_eq!(tr.scalars_per_exchange(6, 3), 792);
    }

    #[test]
    fn rejects_bad_params() {
        assert!(MlpSgdTrainer::new(MlpSgdParams { hidden: 0, ..params(1) }).is_err());
        assert!(MlpSgdTrainer::new(MlpSgdParams { layers: 0, ..params(1) }).is_err());
        assert!(MlpSgdTrainer::new(MlpSgdParams { step: -0.1, ..params(1) }).is_err());
        assert!(MlpSgdTrainer::new(MlpSgdParams { iterations: 0, ..params(1) }).is_err());
    }
}
