//! # Discrete-event cluster simulator
//!
//! The closed-form clock charge (`LatencyModel::round_time` and the
//! straggler critical path `max_i min_{w≤s} α_i(r−w)`) is a first-order
//! model: every node is assumed to cross each gossip barrier at the same
//! global instant, so slack debt cannot carry from one averaging call
//! into the next and a fast node's idle time is never reclaimed. This
//! module replaces that charge with a per-node **completion-time
//! simulation** when the run is configured with `--clock event`.
//!
//! ## Event model
//!
//! Each node `i` executes gossip rounds `0..R` of an averaging call.
//! Round `r` of node `i` may *start* only when its dependency set is
//! complete:
//!
//! * node `i` itself has finished round `r − 1` (a node's own rounds are
//!   serial), and
//! * every gossip neighbour `j` has finished round `r − 1 − s_eff`,
//!   where `s_eff = min(node_slack_i, slack(r))` is the bounded
//!   staleness the schedule grants this round (`s_eff = 0` is the full
//!   barrier: all neighbours must be exactly one round behind or
//!   better).
//!
//! Its completion time is then
//!
//! ```text
//! T_i(r) = max(own T_i(r−1), max_j T_j(r − 1 − s_eff)) + α·m_i(r) + deg_i·bytes/β
//! ```
//!
//! with `m_i(r)` the node's seeded straggler multiplier (1 when the
//! cluster is homogeneous) and `deg_i` its own degree — the closed form
//! charges every node the max degree; the event engine lets low-degree
//! nodes serialize less traffic. Events are processed from a binary
//! heap keyed on `(sim_time, seq)` where `seq` is the deterministic
//! insertion order, so ties break identically on every run and every
//! platform (times compare via `total_cmp`).
//!
//! Dependencies that reach *before the current call* clamp to the
//! neighbour's final pre-call completion time: slack windows never span
//! averaging calls (the same discipline the closed-form sampler
//! enforces via [`StragglerSampler::begin_call`]), which keeps
//! checkpoint/resume at call boundaries exact.
//!
//! ## Relation to the closed form
//!
//! * `σ = 0`, slack 0: **bit-identical**. The maximum-degree node pays
//!   exactly the closed-form charge `α + maxdeg·bytes/β` every round
//!   through the same sequential accumulation, and no other node can
//!   exceed it (round-to-nearest addition is monotone), so the global
//!   clock — the max over nodes — reproduces the closed-form clock
//!   bit for bit, across calls.
//! * `σ > 0`, slack 0: event time ≤ closed-form time, bitwise. Any
//!   dependency chain through the DAG charges per-round terms bounded
//!   by the closed form's `max_i` critical path.  On a complete graph
//!   the two coincide exactly.
//! * slack > 0: the engines intentionally diverge. The closed form
//!   amortizes the fixed barrier `α/(slack+1)` for homogeneous
//!   clusters; the event DAG keeps each node's rounds serial, so a
//!   homogeneous cluster sees no slack benefit (there is no straggler
//!   to overlap). This mirrors the deliberate σ → 0 discontinuity of
//!   the closed-form sampler: slack overlaps heterogeneous stalls, it
//!   never skips homogeneous work.
//!
//! ## Memory
//!
//! The engine stores O(M·degree) adjacency (borrowed from the sparse
//! [`MixingMatrix`] CSR) plus O(M) completion times. Per call it keeps
//! a completion-time ring of `2(s_max+1)+2` rounds per node — the DAG
//! bounds neighbouring nodes to within `s_max + 1` rounds of each
//! other, so no live dependency is ever evicted — and straggler
//! multiplier banks are drawn lazily in round order and retired once
//! every node has passed them, never the full `R × M` table.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::network::{LatencyModel, MixingMatrix, StragglerSampler};
use crate::{Error, Result};

/// Which engine charges simulated seconds for gossip rounds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimClock {
    /// The paper's closed-form charge (default): one global per-round
    /// `dt` from the α-β model / straggler critical path. Bit-identical
    /// to all pre-event-engine behaviour.
    #[default]
    ClosedForm,
    /// Per-node discrete-event simulation (see the module docs).
    Event,
}

impl SimClock {
    /// Parse a CLI/TOML spelling.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "closed-form" => Ok(SimClock::ClosedForm),
            "event" => Ok(SimClock::Event),
            other => Err(Error::Config(format!(
                "unknown clock engine '{other}' (expected closed-form|event)"
            ))),
        }
    }

    /// Canonical spelling (round-trips through [`SimClock::parse`]).
    pub fn describe(&self) -> &'static str {
        match self {
            SimClock::ClosedForm => "closed-form",
            SimClock::Event => "event",
        }
    }

    /// Whether the event engine is selected.
    pub fn is_event(&self) -> bool {
        matches!(self, SimClock::Event)
    }
}

/// A scheduled round-completion event. Ordered by `(time, seq)` with
/// `total_cmp` on time so heap order is total and deterministic.
#[derive(Debug, Clone, Copy)]
struct Ev {
    t: f64,
    seq: u64,
    node: usize,
    round: usize,
}

impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.t.to_bits() == other.t.to_bits() && self.seq == other.seq
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

/// Per-node completion-time state of the discrete-event engine.
///
/// Owned by a [`crate::network::GossipEngine`] when the run selects
/// `--clock event`; persists across averaging calls (that persistence
/// *is* the queueing effect the closed form cannot express) and is
/// checkpointed as `(rounds_done, times)` — see
/// [`EventClock::state`] / [`EventClock::restore_state`].
#[derive(Debug, Clone)]
pub struct EventClock {
    /// CSR adjacency excluding self, ascending per row.
    adj_ptr: Vec<usize>,
    adj: Vec<usize>,
    /// Gossip degree (neighbours excluding self) per node.
    deg: Vec<usize>,
    /// Completion time of each node's last finished round.
    times: Vec<f64>,
    /// Total gossip rounds simulated since construction/restore.
    rounds_done: u64,
}

impl EventClock {
    /// Build from the gossip topology's sparse mixing matrix. All node
    /// clocks start at 0.
    pub fn new(mixing: &MixingMatrix) -> Self {
        let m = mixing.num_nodes();
        let mut adj_ptr = Vec::with_capacity(m + 1);
        let mut adj = Vec::new();
        let mut deg = Vec::with_capacity(m);
        adj_ptr.push(0);
        for i in 0..m {
            let (cols, _) = mixing.neighbors(i);
            adj.extend(cols.iter().copied().filter(|&j| j != i));
            adj_ptr.push(adj.len());
            deg.push(adj_ptr[i + 1] - adj_ptr[i]);
        }
        EventClock { adj_ptr, adj, deg, times: vec![0.0; m], rounds_done: 0 }
    }

    /// Number of simulated nodes.
    pub fn num_nodes(&self) -> usize {
        self.times.len()
    }

    /// The global simulated clock: the slowest node's completion time.
    pub fn global_time(&self) -> f64 {
        self.times.iter().fold(0.0f64, |a, &b| a.max(b))
    }

    /// Per-node completion times of the last finished round.
    pub fn times(&self) -> &[f64] {
        &self.times
    }

    /// Total rounds simulated since construction or the last restore.
    pub fn rounds_done(&self) -> u64 {
        self.rounds_done
    }

    /// The checkpointable state: `(rounds_done, per-node times)`.
    pub fn state(&self) -> (u64, Vec<f64>) {
        (self.rounds_done, self.times.clone())
    }

    /// Restore a checkpointed `(rounds_done, times)` pair. Exact at
    /// averaging-call boundaries (dependency windows never span calls,
    /// so no in-flight event-queue state exists between calls).
    pub fn restore_state(&mut self, rounds_done: u64, times: &[f64]) -> Result<()> {
        if times.len() != self.times.len() {
            return Err(Error::Checkpoint(format!(
                "event clock state carries {} node times, topology has {} nodes",
                times.len(),
                self.times.len()
            )));
        }
        self.times.copy_from_slice(times);
        self.rounds_done = rounds_done;
        Ok(())
    }

    /// Reset all node clocks to 0 (the engine-level `reset_clock`).
    pub fn reset(&mut self) {
        self.times.fill(0.0);
        self.rounds_done = 0;
    }

    fn nbrs(&self, i: usize) -> &[usize] {
        &self.adj[self.adj_ptr[i]..self.adj_ptr[i + 1]]
    }

    /// Simulate one averaging call of `rounds` gossip rounds and return
    /// the new global clock.
    ///
    /// * `payload_bytes` — per-neighbour payload of one round (each
    ///   node serializes `deg_i · payload_bytes`).
    /// * `slack_of_round` — the staleness the schedule grants local
    ///   round `r` (constant for relaxed calls, ramping to 0 at the
    ///   tail of a semi-synchronous call).
    /// * `node_slack` — optional per-node slack caps; node `i`'s
    ///   effective slack is `min(node_slack[i], slack_of_round(r))`.
    /// * `sampler` — the shared straggler stream. One cursor step is
    ///   consumed per round (the same budget the closed form's
    ///   `round_mult` consumes), so the two engines draw identical
    ///   trajectories and share one checkpoint cursor.
    pub fn advance_call<S>(
        &mut self,
        rounds: usize,
        payload_bytes: u64,
        latency: &LatencyModel,
        slack_of_round: S,
        node_slack: Option<&[usize]>,
        mut sampler: Option<&mut StragglerSampler>,
    ) -> f64
    where
        S: Fn(usize) -> usize,
    {
        let m = self.times.len();
        if rounds == 0 || m == 0 {
            return self.global_time();
        }
        let slacks: Vec<usize> = (0..rounds).map(&slack_of_round).collect();
        let s_max = slacks.iter().copied().max().unwrap_or(0);
        // Ring capacity: neighbours stay within s_max + 1 rounds of each
        // other (the DAG forbids a wider spread), so 2(s_max+1)+2 slots
        // per node guarantee no live dependency slot is overwritten.
        let cap = 2 * (s_max + 1) + 2;

        // Final pre-call times: dependencies that reach before round 0
        // of this call clamp here (windows never span calls).
        let base = self.times.clone();
        let mut ring = vec![0.0f64; cap * m];
        // Last completed local round per node (-1 = none this call).
        let mut done: Vec<i64> = vec![-1; m];
        // Next local round not yet scheduled per node.
        let mut next: Vec<usize> = vec![0usize; m];
        let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::with_capacity(2 * m);
        let mut seq: u64 = 0;

        // Straggler multiplier banks, drawn lazily in round order (the
        // cursor stream is strictly sequential) and retired once every
        // node has completed the bank's round.
        let mut banks: VecDeque<Vec<f64>> = VecDeque::new();
        let mut bank_base: usize = 0;
        let mut drawn: usize = 0;
        let mut pops_since_retire: usize = 0;

        // Nodes whose next round may have become schedulable.
        let mut cand: Vec<usize> = (0..m).collect();
        let mut pops: usize = 0;

        loop {
            while let Some(x) = cand.pop() {
                let r = next[x];
                if r >= rounds {
                    continue;
                }
                // Own rounds are serial.
                if r > 0 && done[x] < r as i64 - 1 {
                    continue;
                }
                let s_eff = match node_slack {
                    Some(v) => v[x].min(slacks[r]),
                    None => slacks[r],
                };
                let d = r as i64 - 1 - s_eff as i64;
                if d >= 0 && self.nbrs(x).iter().any(|&k| done[k] < d) {
                    continue;
                }
                // All dependencies final: the start time is exact.
                let mut start = if r == 0 {
                    base[x]
                } else {
                    ring[((r - 1) % cap) * m + x]
                };
                for &k in self.nbrs(x) {
                    let tk = if d < 0 {
                        base[k]
                    } else {
                        ring[(d as usize % cap) * m + k]
                    };
                    if tk > start {
                        start = tk;
                    }
                }
                let mult = match sampler.as_deref_mut() {
                    Some(s) => {
                        while drawn <= r {
                            let mut bank = vec![0.0f64; m];
                            s.node_mults(&mut bank);
                            banks.push_back(bank);
                            drawn += 1;
                        }
                        banks[r - bank_base][x]
                    }
                    None => 1.0,
                };
                let t = start + latency.round_time_mult(mult, self.deg[x], payload_bytes);
                heap.push(Reverse(Ev { t, seq, node: x, round: r }));
                seq += 1;
                next[x] = r + 1;
            }

            let Some(Reverse(ev)) = heap.pop() else { break };
            let (i, r) = (ev.node, ev.round);
            ring[(r % cap) * m + i] = ev.t;
            done[i] = r as i64;
            pops += 1;

            // The completion may unblock this node's next round and each
            // neighbour's next round.
            cand.push(i);
            cand.extend_from_slice(self.nbrs(i));

            pops_since_retire += 1;
            if pops_since_retire >= m && !banks.is_empty() {
                pops_since_retire = 0;
                let min_done = done.iter().copied().min().unwrap_or(-1);
                while (bank_base as i64) < min_done && banks.len() > 1 {
                    banks.pop_front();
                    bank_base += 1;
                }
            }
        }
        debug_assert_eq!(pops, rounds * m, "event DAG deadlocked or double-fired");

        for i in 0..m {
            self.times[i] = ring[((rounds - 1) % cap) * m + i];
        }
        self.rounds_done += rounds as u64;
        self.global_time()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::{NodeLatency, Topology, WeightRule};

    fn mixing(topology: Topology) -> MixingMatrix {
        MixingMatrix::build(&topology, WeightRule::Metropolis).unwrap()
    }

    fn sampler(sigma: f64, seed: u64, corr: f64, m: usize) -> StragglerSampler {
        StragglerSampler::new(NodeLatency { sigma, seed, corr }, m)
    }

    #[test]
    fn sim_clock_parses_and_round_trips() {
        assert_eq!(SimClock::parse("closed-form").unwrap(), SimClock::ClosedForm);
        assert_eq!(SimClock::parse("event").unwrap(), SimClock::Event);
        assert!(SimClock::parse("warp").is_err());
        for c in [SimClock::ClosedForm, SimClock::Event] {
            assert_eq!(SimClock::parse(c.describe()).unwrap(), c);
        }
        assert_eq!(SimClock::default(), SimClock::ClosedForm);
        assert!(SimClock::Event.is_event());
        assert!(!SimClock::ClosedForm.is_event());
    }

    /// σ = 0, slack 0: the event engine reproduces the closed-form
    /// clock bit for bit, including across call boundaries.
    #[test]
    fn homogeneous_full_barrier_is_bit_identical_to_closed_form() {
        let mm = mixing(Topology::Circular { nodes: 8, degree: 1 });
        let lat = LatencyModel::default();
        let mut ev = EventClock::new(&mm);
        let bytes = 1024u64;
        let max_deg = 2usize;
        let mut closed = 0.0f64;
        for rounds in [1usize, 7, 20] {
            let got = ev.advance_call(rounds, bytes, &lat, |_| 0, None, None);
            for _ in 0..rounds {
                closed += lat.round_time(max_deg, bytes);
            }
            assert_eq!(got.to_bits(), closed.to_bits());
            assert_eq!(ev.global_time().to_bits(), closed.to_bits());
        }
        assert_eq!(ev.rounds_done(), 28);
    }

    /// On a complete graph every node shares the global dependency
    /// frontier and the max degree, so even under stragglers the event
    /// engine equals the closed-form critical path exactly.
    #[test]
    fn complete_graph_matches_closed_form_under_stragglers() {
        let m = 6usize;
        let mm = mixing(Topology::Complete { nodes: m });
        let lat = LatencyModel::default();
        let bytes = 2048u64;
        let mut ev = EventClock::new(&mm);
        let mut s_event = sampler(0.4, 77, 0.3, m);
        let mut s_closed = sampler(0.4, 77, 0.3, m);
        let mut closed = 0.0f64;
        for rounds in [12usize, 5] {
            s_closed.begin_call();
            let got = ev.advance_call(rounds, bytes, &lat, |_| 0, None, Some(&mut s_event));
            for _ in 0..rounds {
                let mult = s_closed.round_mult(0);
                closed += lat.round_time_mult(mult, m - 1, bytes);
            }
            assert_eq!(got.to_bits(), closed.to_bits());
        }
        // Both engines consumed the same cursor budget.
        assert_eq!(s_event.state().0, s_closed.state().0);
    }

    /// On sparse topologies the closed form's global critical path is an
    /// upper bound: local barriers never exceed the global one.
    #[test]
    fn event_time_is_bounded_by_closed_form_under_stragglers() {
        let m = 12usize;
        let mm = mixing(Topology::Circular { nodes: m, degree: 1 });
        let lat = LatencyModel::default();
        let bytes = 512u64;
        let rounds = 40usize;
        let mut ev = EventClock::new(&mm);
        let mut s_event = sampler(0.5, 9, 0.0, m);
        let event_t = ev.advance_call(rounds, bytes, &lat, |_| 0, None, Some(&mut s_event));
        let mut s_closed = sampler(0.5, 9, 0.0, m);
        let mut closed = 0.0f64;
        for _ in 0..rounds {
            closed += lat.round_time_mult(s_closed.round_mult(0), 2, bytes);
        }
        assert!(event_t > 0.0);
        assert!(
            event_t <= closed,
            "event {event_t} must not exceed closed form {closed}"
        );
        // On a ring the local barriers genuinely beat the global one.
        assert!(event_t < closed);
    }

    /// Slack relaxes dependencies, so it can only speed the DAG up.
    #[test]
    fn slack_never_increases_event_time() {
        let m = 10usize;
        let mm = mixing(Topology::Circular { nodes: m, degree: 2 });
        let lat = LatencyModel::default();
        let rounds = 30usize;
        let mut strict = EventClock::new(&mm);
        let mut relaxed = EventClock::new(&mm);
        let mut s0 = sampler(0.6, 41, 0.2, m);
        let mut s2 = sampler(0.6, 41, 0.2, m);
        let t0 = strict.advance_call(rounds, 256, &lat, |_| 0, None, Some(&mut s0));
        let t2 = relaxed.advance_call(rounds, 256, &lat, |_| 2, None, Some(&mut s2));
        assert!(t2 <= t0, "slack 2 ({t2}) must not exceed slack 0 ({t0})");
        // Per-node slack caps clamp back toward the strict time.
        let mut capped = EventClock::new(&mm);
        let mut sc = sampler(0.6, 41, 0.2, m);
        let caps = vec![0usize; m];
        let tc = capped.advance_call(rounds, 256, &lat, |_| 2, Some(&caps[..]), Some(&mut sc));
        assert_eq!(tc.to_bits(), t0.to_bits());
    }

    /// The queueing effect the closed form cannot express: staggered
    /// completion times carry across the call boundary, so two
    /// consecutive calls finish sooner than the second call would from
    /// a flat (barrier-aligned) start.
    #[test]
    fn stagger_debt_carries_across_calls() {
        let m = 16usize;
        let mm = mixing(Topology::Circular { nodes: m, degree: 1 });
        let lat = LatencyModel::default();
        let bytes = 128u64;
        let rounds = 25usize;
        let mut ev = EventClock::new(&mm);
        let mut s = sampler(0.7, 3, 0.0, m);
        let g1 = ev.advance_call(rounds, bytes, &lat, |_| 0, None, Some(&mut s));
        let mut s_flat = s.clone();
        let g2 = ev.advance_call(rounds, bytes, &lat, |_| 0, None, Some(&mut s));
        // Replay call 2 from a flat start at the call-1 barrier.
        let mut flat = EventClock::new(&mm);
        flat.restore_state(rounds as u64, &vec![g1; m]).unwrap();
        let gf = flat.advance_call(rounds, bytes, &lat, |_| 0, None, Some(&mut s_flat));
        assert!(g2 <= gf);
        assert!(g2 < gf, "stagger carry-over should beat a flat restart");
    }

    /// Determinism: identical seeds give bit-identical trajectories.
    #[test]
    fn replays_are_bit_identical() {
        let m = 20usize;
        let mm = mixing(Topology::RandomGeometric { nodes: m, radius: 0.45, seed: 5 });
        let lat = LatencyModel::default();
        let run = |_: ()| {
            let mut ev = EventClock::new(&mm);
            let mut s = sampler(0.5, 13, 0.4, m);
            let ramp = |r: usize| if r + 3 < 15 { 3usize } else { 0 };
            ev.advance_call(15, 640, &lat, ramp, None, Some(&mut s));
            ev.times().to_vec()
        };
        let (a, b) = (run(()), run(()));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    /// Checkpoint/restore at a call boundary is bit-exact: splitting a
    /// run across a state round-trip changes nothing.
    #[test]
    fn state_round_trip_is_bit_exact() {
        let m = 9usize;
        let mm = mixing(Topology::Circular { nodes: m, degree: 1 });
        let lat = LatencyModel::default();
        let mut s_a = sampler(0.3, 21, 0.5, m);
        let mut a = EventClock::new(&mm);
        a.advance_call(10, 64, &lat, |_| 1, None, Some(&mut s_a));
        a.advance_call(10, 64, &lat, |_| 1, None, Some(&mut s_a));

        let mut s_b = sampler(0.3, 21, 0.5, m);
        let mut b = EventClock::new(&mm);
        b.advance_call(10, 64, &lat, |_| 1, None, Some(&mut s_b));
        let (rounds_done, times) = b.state();
        let (cursor, g) = s_b.state();
        // Fresh objects restored from the checkpointed state.
        let mut b2 = EventClock::new(&mm);
        b2.restore_state(rounds_done, &times).unwrap();
        let mut s_b2 = sampler(0.3, 21, 0.5, m);
        s_b2.restore_state(cursor, g).unwrap();
        b2.advance_call(10, 64, &lat, |_| 1, None, Some(&mut s_b2));

        assert_eq!(a.rounds_done(), b2.rounds_done());
        for (x, y) in a.times().iter().zip(b2.times()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Mismatched cluster size is rejected.
        let mut wrong = EventClock::new(&mm);
        assert!(wrong.restore_state(3, &[0.0; 4]).is_err());
    }

    /// The lazy multiplier banks never hold the full R×M table: a long
    /// call on a big ring stays O(M·slack) regardless of round count.
    /// (Indirectly pinned here by it simply completing quickly; the
    /// allocation ceiling is pinned by the tests/scale_mem.rs harness.)
    #[test]
    fn long_calls_complete_on_large_rings() {
        let m = 256usize;
        let mm = mixing(Topology::Circular { nodes: m, degree: 1 });
        let lat = LatencyModel::default();
        let mut ev = EventClock::new(&mm);
        let mut s = sampler(0.2, 1, 0.0, m);
        let t = ev.advance_call(500, 64, &lat, |_| 1, None, Some(&mut s));
        assert!(t.is_finite() && t > 0.0);
        assert_eq!(ev.rounds_done(), 500);
    }
}
