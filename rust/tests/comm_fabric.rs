//! Integration: the pluggable `CommFabric` API — centralized-equivalent
//! training under relaxed communication schedules, adaptive-δ /
//! communication-period savings, the heterogeneous (straggler) latency
//! model, iteration-level staleness, and bit-identical
//! checkpoint/resume of seeded schedules.

use dssfn::data::lookup;
use dssfn::network::{
    AdaptiveDeltaPolicy, CommSchedule, CompressionConfig, NodeLatency, StalenessSchedule,
};
use dssfn::session::{SessionBuilder, StepEvent};
use dssfn::{resume_session, Checkpoint};

/// A small-but-real configuration on the synthetic mnist-small task
/// (P = 64, Q = 10): one structured layer plus the input solve.
fn mnist_small_builder() -> SessionBuilder {
    SessionBuilder::new()
        .dataset("mnist-small")
        .seed(11)
        .layers(1)
        .hidden_extra(30)
        .admm_iterations(30)
        .nodes(6)
        .degree(2)
        .gossip_delta(1e-8)
        .threads(2)
}

#[test]
fn semisync_final_cost_within_5_percent_of_synchronous() {
    let (_, sync_report) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (_, semi_report) = mnist_small_builder()
        .staleness(2)
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();

    let sync_cost = sync_report.layers.last().unwrap().final_cost().unwrap();
    let semi_cost = semi_report.layers.last().unwrap().final_cost().unwrap();
    assert!(
        (semi_cost - sync_cost).abs() <= 0.05 * sync_cost.abs(),
        "semisync final-layer cost {semi_cost} vs sync {sync_cost}"
    );
    // Accuracy is preserved too, not just the objective.
    assert!(
        (semi_report.train_accuracy - sync_report.train_accuracy).abs() < 0.05,
        "train acc {} vs {}",
        semi_report.train_accuracy,
        sync_report.train_accuracy
    );
    assert!(semi_report.mode.contains("semisync(s=2)"), "{}", semi_report.mode);
    // Staleness buys pipeline of rounds: the flush rounds add traffic,
    // but the relaxed barrier makes the simulated clock run faster.
    assert!(semi_report.comm_total.rounds > sync_report.comm_total.rounds);
    assert!(
        semi_report.simulated_comm_secs < sync_report.simulated_comm_secs,
        "semisync sim time {} should beat sync {}",
        semi_report.simulated_comm_secs,
        sync_report.simulated_comm_secs
    );
}

#[test]
fn adaptive_delta_saves_bytes_without_hurting_cost() {
    let (_, fixed_report) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();

    let mut session = mnist_small_builder()
        .adaptive_delta(AdaptiveDeltaPolicy {
            max_delta: 1e-4,
            plateau: 0.02,
            loosen: 10.0,
            period: 1,
        })
        .build()
        .unwrap();
    let mut adjustments = 0usize;
    while let Some(ev) = session.step().unwrap() {
        if let StepEvent::DeltaAdjusted { delta, .. } = ev {
            adjustments += 1;
            assert!(delta <= 1e-4 && delta >= 1e-8, "δ {delta} escaped its bounds");
        }
    }
    let (_, adaptive_report) = session.finish().unwrap();

    assert!(adjustments > 0, "the controller never adjusted δ");
    assert!(
        adaptive_report.comm_total.bytes < fixed_report.comm_total.bytes,
        "adaptive δ did not save traffic: {} vs {}",
        adaptive_report.comm_total.bytes,
        fixed_report.comm_total.bytes
    );
    let fixed_cost = fixed_report.layers.last().unwrap().final_cost().unwrap();
    let adaptive_cost = adaptive_report.layers.last().unwrap().final_cost().unwrap();
    assert!(
        adaptive_cost <= fixed_cost * 1.01 + 1e-12,
        "adaptive δ worsened the final cost beyond 1%: {adaptive_cost} vs {fixed_cost}"
    );
    assert!(adaptive_report.mode.contains("adaptive-δ"), "{}", adaptive_report.mode);
}

#[test]
fn lossy_schedule_trains_to_comparable_accuracy() {
    let (_, sync_report) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (_, lossy_report) = mnist_small_builder()
        .comm_fabric(CommSchedule::Lossy { loss_p: 0.2 })
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let sync_cost = sync_report.layers.last().unwrap().final_cost().unwrap();
    let lossy_cost = lossy_report.layers.last().unwrap().final_cost().unwrap();
    assert!(
        (lossy_cost - sync_cost).abs() <= 0.05 * sync_cost.abs(),
        "lossy final-layer cost {lossy_cost} vs sync {sync_cost}"
    );
    // The compensation runs extra rounds, so the drop schedule costs
    // rounds, not accuracy.
    assert!(lossy_report.comm_total.rounds > sync_report.comm_total.rounds);
    assert!(lossy_report.mode.contains("lossy(p=0.2)"), "{}", lossy_report.mode);
}

/// Checkpoint/resume must replay seeded schedules bit-identically: the
/// fabric's call cursor and the adaptive controller's working δ are
/// part of the snapshot.
#[test]
fn semisync_adaptive_run_resumes_bit_identically() {
    let task = std::sync::Arc::new(lookup("quickstart").unwrap().generator(5).generate().unwrap());
    let builder = || {
        SessionBuilder::new()
            .shared_task(std::sync::Arc::clone(&task))
            .seed(5)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(12)
            .nodes(4)
            .degree(1)
            .gossip_delta(1e-8)
            .threads(2)
            .staleness(2)
            .adaptive_delta(AdaptiveDeltaPolicy {
                max_delta: 1e-4,
                plateau: 0.05,
                loosen: 10.0,
                period: 1,
            })
    };
    let (one_model, one_report) = builder().build().unwrap().run_to_completion().unwrap();
    let one_model = one_model.into_ssfn().unwrap();

    // Interrupt mid-layer-1, serialize, restore, finish.
    let mut session = builder().build().unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 5, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    let bytes = ck.to_bytes();
    drop(session);

    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(report.total_gossip_rounds(), one_report.total_gossip_rounds());
}

/// A heterogeneous (per-round lognormal-α) cluster for the straggler
/// tests, with partially persistent slowness (AR(1) ρ = 0.6).
fn straggler() -> NodeLatency {
    NodeLatency { sigma: 0.8, seed: 17, corr: 0.6 }
}

/// The straggler model's simulated-seconds ordering: a heterogeneous
/// cluster makes the synchronous barrier pay the slowest node (slower
/// than the homogeneous run), while the semi-sync fabric's relaxed
/// rounds pay the amortized median and beat it. The trained model and
/// the traffic accounting are untouched — stragglers slow the clock,
/// never the math.
#[test]
fn straggler_sync_pays_the_max_node_semisync_recovers_the_median() {
    let (homog_model, homog) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (het_model, het) = mnist_small_builder()
        .node_latency(straggler())
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (_, semi_het) = mnist_small_builder()
        .node_latency(straggler())
        .staleness(2)
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();

    // Same math, same bytes — only the clock knows about stragglers.
    let homog_model = homog_model.into_ssfn().unwrap();
    let het_model = het_model.into_ssfn().unwrap();
    assert_eq!(het_model.output().max_abs_diff(homog_model.output()), 0.0);
    for (a, b) in het_model.weights().iter().zip(homog_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(het.comm_total, homog.comm_total);
    assert!(het.mode.contains("straggler"), "{}", het.mode);

    // Heterogeneity slows the synchronous barrier...
    assert!(
        het.simulated_comm_secs > homog.simulated_comm_secs,
        "het sync {} should exceed homogeneous sync {}",
        het.simulated_comm_secs,
        homog.simulated_comm_secs
    );
    // ... and the semi-sync schedule recovers most of it: under the
    // same straggler draw its relaxed rounds beat the synchronous run.
    assert!(
        semi_het.simulated_comm_secs < het.simulated_comm_secs,
        "semisync under stragglers {} should beat sync under stragglers {}",
        semi_het.simulated_comm_secs,
        het.simulated_comm_secs
    );
}

/// The acceptance criterion for iteration-level staleness: an s=2 run
/// on mnist-small lands within 5% of the synchronous final-layer cost
/// while its simulated seconds strictly beat the synchronous run under
/// the heterogeneous node-latency model — and it ships exactly the same
/// bytes (staleness relaxes waiting, not traffic).
#[test]
fn iteration_staleness_matches_sync_cost_and_beats_its_clock() {
    let (_, sync_report) = mnist_small_builder()
        .node_latency(straggler())
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (_, stale_report) = mnist_small_builder()
        .node_latency(straggler())
        .iter_staleness(2)
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();

    let sync_cost = sync_report.layers.last().unwrap().final_cost().unwrap();
    let stale_cost = stale_report.layers.last().unwrap().final_cost().unwrap();
    assert!(
        (stale_cost - sync_cost).abs() <= 0.05 * sync_cost.abs(),
        "iteration-staleness final-layer cost {stale_cost} vs sync {sync_cost}"
    );
    assert!(
        (stale_report.train_accuracy - sync_report.train_accuracy).abs() < 0.05,
        "train acc {} vs {}",
        stale_report.train_accuracy,
        sync_report.train_accuracy
    );
    assert!(stale_report.mode.contains("iter-stale(s=2)"), "{}", stale_report.mode);
    // Same rounds, same bytes: the relaxation is in the waiting.
    assert_eq!(stale_report.comm_total, sync_report.comm_total);
    assert!(
        stale_report.simulated_comm_secs < sync_report.simulated_comm_secs,
        "iteration staleness sim time {} should strictly beat sync {}",
        stale_report.simulated_comm_secs,
        sync_report.simulated_comm_secs
    );
}

/// L-FGADMM communication-period doubling: with the δ controller held
/// fixed (max_delta = base δ), the period knob alone skips whole
/// averaging calls on plateaus — measurably fewer gossip rounds and
/// `GossipRound` events at a near-unchanged final cost.
#[test]
fn adaptive_period_doubling_skips_averaging_calls() {
    let (_, fixed_report) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();

    let mut session = mnist_small_builder()
        .adaptive_delta(AdaptiveDeltaPolicy {
            max_delta: 1e-8, // = base δ: isolates the period effect
            plateau: 0.02,
            loosen: 10.0,
            period: 8,
        })
        .build()
        .unwrap();
    let mut gossip_events = 0usize;
    let mut iterations = 0usize;
    while let Some(ev) = session.step().unwrap() {
        match ev {
            StepEvent::GossipRound { .. } => gossip_events += 1,
            StepEvent::AdmmIteration { .. } => iterations += 1,
            _ => {}
        }
    }
    let (_, period_report) = session.finish().unwrap();

    assert!(
        gossip_events < iterations,
        "period doubling never skipped an averaging ({gossip_events} events over \
         {iterations} iterations)"
    );
    assert!(
        period_report.total_gossip_rounds() < fixed_report.total_gossip_rounds(),
        "period doubling saved no rounds: {} vs {}",
        period_report.total_gossip_rounds(),
        fixed_report.total_gossip_rounds()
    );
    assert!(
        period_report.comm_total.bytes < fixed_report.comm_total.bytes,
        "period doubling saved no bytes"
    );
    let fixed_cost = fixed_report.layers.last().unwrap().final_cost().unwrap();
    let period_cost = period_report.layers.last().unwrap().final_cost().unwrap();
    assert!(
        (period_cost - fixed_cost).abs() <= 0.05 * fixed_cost.abs(),
        "period doubling moved the final cost beyond 5%: {period_cost} vs {fixed_cost}"
    );
}

/// Iteration-staleness runs — seeded per-node draws, history ring,
/// cursor — checkpoint and resume bit-identically, straggler clock
/// included.
#[test]
fn iteration_staleness_run_resumes_bit_identically() {
    let task = std::sync::Arc::new(lookup("quickstart").unwrap().generator(5).generate().unwrap());
    let builder = || {
        SessionBuilder::new()
            .shared_task(std::sync::Arc::clone(&task))
            .seed(5)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(12)
            .nodes(4)
            .degree(1)
            .gossip_delta(1e-8)
            .threads(2)
            .iter_staleness(2)
            .node_latency(straggler())
    };
    let (one_model, one_report) = builder().build().unwrap().run_to_completion().unwrap();
    let one_model = one_model.into_ssfn().unwrap();

    // Interrupt mid-layer-1 (inside the staleness window), serialize,
    // restore, finish.
    let mut session = builder().build().unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 5, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    let bytes = ck.to_bytes();
    drop(session);

    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(
        report.simulated_comm_secs.to_bits(),
        one_report.simulated_comm_secs.to_bits(),
        "straggler clock drifted across resume"
    );
}

/// Event-clock runs — per-node completion times, round counter,
/// straggler cursor — checkpoint and resume bit-identically (the v6
/// event state). The per-node DAG makes the interrupted and one-shot
/// clocks agree only if the checkpoint carries every node's time, not
/// just the global maximum, so this is the test that fails if the v6
/// runtime block is dropped or mis-ordered.
#[test]
fn event_clock_run_resumes_bit_identically() {
    use dssfn::simulator::SimClock;
    let task = std::sync::Arc::new(lookup("quickstart").unwrap().generator(9).generate().unwrap());
    let builder = || {
        SessionBuilder::new()
            .shared_task(std::sync::Arc::clone(&task))
            .seed(9)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(12)
            .nodes(4)
            .degree(1)
            .gossip_delta(1e-8)
            .threads(2)
            .node_latency(straggler())
            .clock(SimClock::Event)
    };
    let (one_model, one_report) = builder().build().unwrap().run_to_completion().unwrap();
    let one_model = one_model.into_ssfn().unwrap();
    assert!(one_report.mode.contains("clock=event"), "{}", one_report.mode);

    // Interrupt mid-layer-1, serialize, restore, finish.
    let mut session = builder().build().unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 5, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    let bytes = ck.to_bytes();
    drop(session);

    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(
        report.simulated_comm_secs.to_bits(),
        one_report.simulated_comm_secs.to_bits(),
        "event clock drifted across resume"
    );
    // The relaxation the event engine models is real: the same run
    // under the closed-form barrier is never faster.
    let (_, barrier_report) = SessionBuilder::new()
        .shared_task(std::sync::Arc::clone(&task))
        .seed(9)
        .layers(2)
        .hidden_extra(12)
        .admm_iterations(12)
        .nodes(4)
        .degree(1)
        .gossip_delta(1e-8)
        .threads(2)
        .node_latency(straggler())
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    assert!(
        report.simulated_comm_secs <= barrier_report.simulated_comm_secs,
        "event clock {} slower than the closed-form barrier {}",
        report.simulated_comm_secs,
        barrier_report.simulated_comm_secs
    );
}

/// Liang et al.'s Fig.-2 fixed-delay setting: a `FixedLag` schedule
/// consumes no randomness, so two fresh runs are bit-identical, and a
/// mid-layer checkpoint resumes bit-identically — straggler clock
/// (per-round AR(1) draws, v4 cursor + state) included.
#[test]
fn fixed_lag_schedule_is_deterministic_and_resumes_bit_identically() {
    let task = std::sync::Arc::new(lookup("quickstart").unwrap().generator(5).generate().unwrap());
    let builder = || {
        SessionBuilder::new()
            .shared_task(std::sync::Arc::clone(&task))
            .seed(5)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(12)
            .nodes(4)
            .degree(1)
            .gossip_delta(1e-8)
            .threads(2)
            .iter_staleness(2)
            .iter_schedule(StalenessSchedule::FixedLag(2))
            .node_latency(straggler())
    };
    let (one_model, one_report) = builder().build().unwrap().run_to_completion().unwrap();
    let one_model = one_model.into_ssfn().unwrap();
    assert!(one_report.mode.contains("fixed-lag(2)"), "{}", one_report.mode);

    // Two fresh runs are identical (no draws to diverge on)...
    let (two_model, two_report) = builder().build().unwrap().run_to_completion().unwrap();
    let two_model = two_model.into_ssfn().unwrap();
    assert_eq!(two_model.output().max_abs_diff(one_model.output()), 0.0);
    assert_eq!(two_report.full_cost_curve(), one_report.full_cost_curve());

    // ... and the fixed ages genuinely differ from the i.i.d. draws.
    let (_, iid_report) = SessionBuilder::new()
        .shared_task(std::sync::Arc::clone(&task))
        .seed(5)
        .layers(2)
        .hidden_extra(12)
        .admm_iterations(12)
        .nodes(4)
        .degree(1)
        .gossip_delta(1e-8)
        .threads(2)
        .iter_staleness(2)
        .node_latency(straggler())
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    assert_ne!(iid_report.full_cost_curve(), one_report.full_cost_curve());

    // Interrupt mid-layer-1, serialize, restore, finish: bit-identical,
    // simulated clock included.
    let mut session = builder().build().unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 5, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    let bytes = ck.to_bytes();
    drop(session);
    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    assert_eq!(ck.comm_config().iter_schedule, StalenessSchedule::FixedLag(2));
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();
    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(
        report.simulated_comm_secs.to_bits(),
        one_report.simulated_comm_secs.to_bits(),
        "per-round straggler clock drifted across resume"
    );
}

/// The `OneSlow` critical path: only the lagged node earns barrier
/// slack, so the simulated clock orders fixed-lag (every node relaxed)
/// ≤ one-slow (one node relaxed) ≤ fully synchronous — with identical
/// traffic and bit-identical models throughout. And under fully
/// persistent slowness (ρ = 1) the lagged node is *the* node charged on
/// the critical path: slack hides transient spikes, never a node that
/// is slow every round, so every variant charges exactly the
/// synchronous clock.
#[test]
fn one_slow_lagged_node_is_the_one_charged_on_the_critical_path() {
    let transient = NodeLatency { sigma: 0.8, seed: 17, corr: 0.0 };
    let run = |schedule: Option<StalenessSchedule>, latency: NodeLatency| {
        let mut b = mnist_small_builder().node_latency(latency);
        if let Some(s) = schedule {
            b = b.iter_staleness(2).iter_schedule(s);
        }
        let (model, report) = b.build().unwrap().run_to_completion().unwrap();
        (model.into_ssfn().unwrap(), report)
    };

    let (sync_model, sync) = run(None, transient);
    let (one_model, one) = run(Some(StalenessSchedule::OneSlow { node: 2, lag: 2 }), transient);
    let (_fixed_model, fixed) = run(Some(StalenessSchedule::FixedLag(2)), transient);

    // Identical traffic; the relaxation is in the waiting.
    assert_eq!(one.comm_total, sync.comm_total);
    assert_eq!(fixed.comm_total, sync.comm_total);
    assert!(one.mode.contains("one-slow(node=2, lag=2)"), "{}", one.mode);

    // fixed ≤ one-slow ≤ sync: every node's slack ≥ one node's slack ≥
    // none (same per-round draws — the round counts are identical).
    assert!(
        one.simulated_comm_secs < sync.simulated_comm_secs,
        "one-slow {} did not beat sync {}",
        one.simulated_comm_secs,
        sync.simulated_comm_secs
    );
    assert!(
        fixed.simulated_comm_secs < one.simulated_comm_secs,
        "fixed-lag {} did not beat one-slow {} (only the lagged node may hide)",
        fixed.simulated_comm_secs,
        one.simulated_comm_secs
    );

    // Staleness perturbs the iterate (stale consensus reads), so the
    // models are *not* bit-identical to the no-staleness run — but the
    // synchronous drain keeps the final-layer objective within the same
    // 5% acceptance band the i.i.d. schedule is held to.
    let sync_cost = sync.layers.last().unwrap().final_cost().unwrap();
    for (name, report) in [("one-slow", &one), ("fixed-lag", &fixed)] {
        let cost = report.layers.last().unwrap().final_cost().unwrap();
        assert!(
            (cost - sync_cost).abs() <= 0.05 * sync_cost.abs(),
            "{name} final-layer cost {cost} vs sync {sync_cost}"
        );
    }

    // ρ = 1: each node keeps one multiplier forever. The lagged node is
    // slow *every* round, so its window-min is itself — the critical
    // path charges it in full and one-slow's clock equals sync's, bit
    // for bit. And stragglers never touch the math: the persistent-ρ
    // one-slow model is bit-identical to the transient-ρ one (same
    // schedule, same seed — only the simulated clock differs).
    let persistent = NodeLatency { sigma: 0.8, seed: 17, corr: 1.0 };
    let (sync_p_model, sync_p) = run(None, persistent);
    let (one_p_model, one_p) =
        run(Some(StalenessSchedule::OneSlow { node: 2, lag: 2 }), persistent);
    assert_eq!(
        one_p.simulated_comm_secs.to_bits(),
        sync_p.simulated_comm_secs.to_bits(),
        "persistent slowness must not hide inside the slack window"
    );
    assert_eq!(one_p_model.output().max_abs_diff(one_model.output()), 0.0);
    assert_eq!(sync_p_model.output().max_abs_diff(sync_model.output()), 0.0);
}

/// The acceptance criterion for compressed gossip: 4-bit quantization
/// and top-10% sparsification (each with per-edge error feedback) land
/// within 5% of the uncompressed final-layer cost on mnist-small while
/// billing strictly fewer bytes — over an *identical* logical exchange,
/// because the round count B(δ) comes from the spectral gap, not the
/// values.
#[test]
fn compressed_gossip_matches_sync_cost_with_strictly_fewer_bytes() {
    let (_, plain) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let plain_cost = plain.layers.last().unwrap().final_cost().unwrap();
    for spec in ["q4", "topk:0.1"] {
        let (_, report) = mnist_small_builder()
            .compression(CompressionConfig::parse(spec).unwrap())
            .build()
            .unwrap()
            .run_to_completion()
            .unwrap();
        let cost = report.layers.last().unwrap().final_cost().unwrap();
        assert!(
            (cost - plain_cost).abs() <= 0.05 * plain_cost.abs(),
            "{spec} final-layer cost {cost} vs uncompressed {plain_cost}"
        );
        assert_eq!(
            (report.comm_total.rounds, report.comm_total.scalars),
            (plain.comm_total.rounds, plain.comm_total.scalars),
            "{spec}: the logical exchange must not change"
        );
        assert!(
            report.comm_total.bytes < plain.comm_total.bytes,
            "{spec} billed {} bytes, not fewer than uncompressed {}",
            report.comm_total.bytes,
            plain.comm_total.bytes
        );
        assert!(report.mode.contains(&format!("compress={spec}")), "{}", report.mode);
    }
}

/// Compression composes with the relaxed schedules: under semisync,
/// lossy, and the adaptive-δ controller, a q4 run stays within 5% of
/// the same schedule's uncompressed final-layer cost and bills strictly
/// fewer bytes. (Under adaptive δ the round counts may legitimately
/// differ — the controller reads the compressed objective — so only the
/// value-independent schedules pin the logical exchange.)
#[test]
fn compression_composes_with_every_relaxed_schedule() {
    let q4 = || CompressionConfig::parse("q4").unwrap();
    let cases: [(&str, fn(SessionBuilder) -> SessionBuilder); 3] = [
        ("semisync", |b| b.staleness(2)),
        ("lossy", |b| b.comm_fabric(CommSchedule::Lossy { loss_p: 0.2 })),
        ("adaptive-δ", |b| {
            b.adaptive_delta(AdaptiveDeltaPolicy {
                max_delta: 1e-4,
                plateau: 0.02,
                loosen: 10.0,
                period: 1,
            })
        }),
    ];
    for (name, shape) in cases {
        let (_, plain) = shape(mnist_small_builder())
            .build()
            .unwrap()
            .run_to_completion()
            .unwrap();
        let (_, comp) = shape(mnist_small_builder())
            .compression(q4())
            .build()
            .unwrap()
            .run_to_completion()
            .unwrap();
        let plain_cost = plain.layers.last().unwrap().final_cost().unwrap();
        let comp_cost = comp.layers.last().unwrap().final_cost().unwrap();
        assert!(
            (comp_cost - plain_cost).abs() <= 0.05 * plain_cost.abs(),
            "{name}+q4 final-layer cost {comp_cost} vs uncompressed {plain_cost}"
        );
        assert!(
            comp.comm_total.bytes < plain.comm_total.bytes,
            "{name}+q4 billed {} bytes, not fewer than uncompressed {}",
            comp.comm_total.bytes,
            plain.comm_total.bytes
        );
        if name != "adaptive-δ" {
            assert_eq!(
                (comp.comm_total.rounds, comp.comm_total.scalars),
                (plain.comm_total.rounds, plain.comm_total.scalars),
                "{name}+q4: seeded schedules are value-independent"
            );
        }
        assert!(comp.mode.contains("compress=q4"), "{}", comp.mode);
    }
}

/// Compressed runs — dither cursor, per-edge error-feedback
/// accumulators (non-zero by mid-layer-1: every quantized round leaves
/// residuals) — checkpoint and resume bit-identically under the
/// semisync schedule: the v7 runtime block carries the compressor's
/// whole history-dependent state.
#[test]
fn quantized_semisync_run_resumes_bit_identically() {
    let task = std::sync::Arc::new(lookup("quickstart").unwrap().generator(5).generate().unwrap());
    let builder = || {
        SessionBuilder::new()
            .shared_task(std::sync::Arc::clone(&task))
            .seed(5)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(12)
            .nodes(4)
            .degree(1)
            .gossip_delta(1e-8)
            .threads(2)
            .staleness(2)
            .compression(CompressionConfig::parse("q4").unwrap())
    };
    let (one_model, one_report) = builder().build().unwrap().run_to_completion().unwrap();
    let one_model = one_model.into_ssfn().unwrap();
    assert!(one_report.mode.contains("compress=q4"), "{}", one_report.mode);

    // Interrupt mid-layer-1 (deep in the compressed dither stream),
    // serialize, restore, finish.
    let mut session = builder().build().unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 5, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    assert_eq!(ck.comm_config().compression.describe(), "q4");
    let bytes = ck.to_bytes();
    drop(session);

    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(
        report.simulated_comm_secs.to_bits(),
        one_report.simulated_comm_secs.to_bits(),
        "compressed-payload clock drifted across resume"
    );
}

/// The synchronous fabric really is the old path: a default-schedule
/// session and one built through the explicit `comm_fabric(Synchronous)`
/// knob produce bit-identical models and ledgers.
#[test]
fn explicit_synchronous_fabric_is_bit_identical_to_default() {
    let (m1, r1) = mnist_small_builder()
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let (m2, r2) = mnist_small_builder()
        .comm_fabric(CommSchedule::Synchronous)
        .build()
        .unwrap()
        .run_to_completion()
        .unwrap();
    let m1 = m1.into_ssfn().unwrap();
    let m2 = m2.into_ssfn().unwrap();
    assert_eq!(m1.output().max_abs_diff(m2.output()), 0.0);
    for (a, b) in m1.weights().iter().zip(m2.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(r1.comm_total, r2.comm_total);
    assert_eq!(r1.full_cost_curve(), r2.full_cost_curve());
}
