//! Zero-allocation contract of the ADMM hot path, enforced with a
//! counting global allocator.
//!
//! The paper's "low complexity" claim is a per-iteration statement: with
//! `K = 100` iterations per layer and `M` nodes, anything the inner loop
//! allocates is paid `K·M·L` times per run. After `prepare_layer` builds
//! the per-node workspaces (and one warmup iteration populates the lazy
//! Gram inverse, the GEMM packing arena and the gossip scratch bank),
//! the steady-state iteration must perform **zero** heap allocations.
//!
//! Everything runs inside a single `#[test]` so no sibling test thread
//! can allocate concurrently and pollute the counter.

use dssfn::admm::{solve_decentralized, AdmmParams, Consensus, LayerLocalSolver, NodeState};
use dssfn::linalg::Matrix;
use dssfn::network::{CommLedger, GossipEngine, LatencyModel, MixingMatrix, Topology, WeightRule};
use dssfn::util::{Rng, Xoshiro256StarStar};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOC_COUNT: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_COUNT.load(Ordering::SeqCst)
}

const Q: usize = 3;
const N: usize = 20;
const M: usize = 3;
const J_PER_NODE: usize = 40;

fn node_data(seed: u64) -> (Matrix, Matrix) {
    let mut rng = Xoshiro256StarStar::seed_from_u64(seed);
    let y = Matrix::from_fn(N, J_PER_NODE, |_, _| rng.uniform(-1.0, 1.0));
    let t = Matrix::from_fn(Q, J_PER_NODE, |_, _| rng.uniform(0.0, 1.0));
    (y, t)
}

fn build_solvers(mu: f64) -> Vec<LayerLocalSolver> {
    (0..M)
        .map(|i| {
            let (y, t) = node_data(100 + i as u64);
            LayerLocalSolver::new(&y, &t, mu).unwrap()
        })
        .collect()
}

fn gossip_engine() -> GossipEngine {
    let mix = MixingMatrix::build(
        &Topology::Circular { nodes: M, degree: 1 },
        WeightRule::EqualNeighbor,
    )
    .unwrap();
    GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default())
}

/// One full exact-consensus ADMM iteration over preallocated state —
/// exactly the sequence `solve_decentralized` runs per iteration.
fn exact_iteration(
    solvers: &[LayerLocalSolver],
    states: &mut [NodeState],
    s_vals: &mut [Matrix],
    avg: &mut Matrix,
    eps: f64,
) -> f64 {
    for (st, solver) in states.iter_mut().zip(solvers) {
        let NodeState { o, lambda, z } = st;
        solver.o_update_into(z, lambda, o).unwrap();
    }
    for (sv, st) in s_vals.iter_mut().zip(states.iter()) {
        sv.copy_from(&st.o).unwrap();
        sv.axpy(1.0, &st.lambda).unwrap();
    }
    GossipEngine::exact_average_into(s_vals, avg).unwrap();
    for sv in s_vals.iter_mut() {
        sv.copy_from(avg).unwrap();
    }
    let mut cost = 0.0;
    for (st, solver) in states.iter_mut().zip(solvers) {
        st.z.copy_from(&s_vals[0]).unwrap();
        st.z.project_frobenius(eps);
        st.lambda.axpy(1.0, &st.o).unwrap();
        st.lambda.axpy(-1.0, &st.z).unwrap();
        cost += solver.cost(&st.z).unwrap();
    }
    cost
}

/// Full decentralized solve (gossip consensus) with fresh solvers and a
/// fresh engine, as a closure target for the K-independence check.
fn full_gossip_solve(iterations: usize) -> f64 {
    let solvers = build_solvers(1.0);
    let engine = gossip_engine();
    let params = AdmmParams { mu: 1.0, eps: 2.0 * Q as f64, iterations };
    let sol = solve_decentralized(
        &solvers,
        Q,
        N,
        &params,
        &Consensus::Gossip { engine: &engine, delta: 1e-9 },
    )
    .unwrap();
    *sol.cost_curve.last().unwrap()
}

#[test]
fn admm_hot_path_is_allocation_free_in_steady_state() {
    // ---- (a) steady-state iteration: exactly zero allocations ----
    let solvers = build_solvers(1.0);
    let mut states: Vec<NodeState> = (0..M).map(|_| NodeState::zeros(Q, N)).collect();
    let mut s_vals: Vec<Matrix> = (0..M).map(|_| Matrix::zeros(Q, N)).collect();
    let mut avg = Matrix::zeros(Q, N);
    let eps = 2.0 * Q as f64;
    // Warmup: builds the lazy Gram inverse and grows the thread-local
    // GEMM packing arena to its steady-state size.
    for _ in 0..2 {
        exact_iteration(&solvers, &mut states, &mut s_vals, &mut avg, eps);
    }
    let before = allocs();
    let mut last_cost = f64::INFINITY;
    for _ in 0..10 {
        last_cost = exact_iteration(&solvers, &mut states, &mut s_vals, &mut avg, eps);
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state ADMM iterations allocated {} times",
        after - before
    );
    assert!(last_cost.is_finite() && last_cost >= 0.0);

    // ---- (b) whole-solve allocation count is independent of K ----
    // Everything a solve allocates is setup (states, curves, scratch
    // banks, Gram inverse); the iteration count contributes nothing.
    full_gossip_solve(3); // warmup: packing arena, thread-local init
    let c0 = allocs();
    let cost_short = full_gossip_solve(5);
    let solve_short = allocs() - c0;
    let c1 = allocs();
    let cost_long = full_gossip_solve(50);
    let solve_long = allocs() - c1;
    assert_eq!(
        solve_short, solve_long,
        "per-iteration allocations leaked into the solve loop \
         (K=5: {solve_short} allocs, K=50: {solve_long} allocs)"
    );
    assert!(cost_short.is_finite() && cost_long.is_finite());

    // ---- (c) gossip rounds reuse the persistent scratch bank ----
    let engine = gossip_engine();
    let mut rng = Xoshiro256StarStar::seed_from_u64(7);
    let mut vals: Vec<Matrix> = (0..M)
        .map(|_| Matrix::from_fn(Q, N, |_, _| rng.uniform(-1.0, 1.0)))
        .collect();
    engine.mix_rounds(&mut vals, 2).unwrap(); // warmup: builds the bank
    let before = allocs();
    engine.mix_rounds(&mut vals, 8).unwrap();
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "gossip rounds allocated {} times in steady state",
        after - before
    );
}
