//! Scale-memory pin: a 1024-node cluster simulation never materializes
//! a dense M×M mixing bank.
//!
//! The sparse CSR rewrite of [`dssfn::network::MixingMatrix`] is the
//! load-bearing change that takes the simulator from tens of nodes to
//! thousands — O(M·degree) stored entries instead of M² f64s. This test
//! pins the invariant mechanically: a counting `#[global_allocator]`
//! records the largest single allocation made while building the mixing
//! state and driving gossip (straggler sampler and event clock
//! installed), and asserts it stays far below the 8·M² bytes a dense
//! bank would need. A regression that reintroduces any dense M×M
//! structure — the matrix itself, a scratch copy, a dense spectral
//! workspace — fails here, not in a profiler.
//!
//! This file is its own test binary, so the allocator hook observes
//! nothing but this test.

use dssfn::linalg::Matrix;
use dssfn::network::{
    CommLedger, GossipEngine, LatencyModel, MixingMatrix, NodeLatency, Topology, WeightRule,
};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Forwards to the system allocator, recording the largest single
/// allocation seen while `TRACKING` is on.
struct CountingAlloc;

static TRACKING: AtomicBool = AtomicBool::new(false);
static LARGEST: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            LARGEST.fetch_max(layout.size(), Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if TRACKING.load(Ordering::Relaxed) {
            LARGEST.fetch_max(new_size, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn thousand_node_cluster_never_allocates_a_dense_bank() {
    const M: usize = 1024;
    // A dense mixing bank would be one 8·M² = 8 MiB allocation; the
    // whole sparse pipeline should stay two orders of magnitude below.
    const DENSE_BANK: usize = 8 * M * M;
    const SPARSE_CEILING: usize = 2 << 20;

    let topologies = [
        ("ring", Topology::Circular { nodes: M, degree: 2 }, WeightRule::EqualNeighbor),
        (
            "rgg",
            Topology::RandomGeometric {
                nodes: M,
                radius: ((M as f64).ln() / M as f64).sqrt(),
                seed: 42,
            },
            WeightRule::Metropolis,
        ),
    ];

    for (name, topo, rule) in topologies {
        LARGEST.store(0, Ordering::Relaxed);
        TRACKING.store(true, Ordering::Relaxed);

        // The full engine-level pipeline at M = 1024: sparse build
        // (including the spectral λ₂ analysis), straggler sampler,
        // event clock, and a dozen gossip rounds over per-node payloads.
        let mix = MixingMatrix::build(&topo, rule).unwrap();
        assert!(mix.nnz() < M * M / 8, "{name}: mixing state is not sparse");
        let mut engine =
            GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default());
        engine.set_straggler(NodeLatency { sigma: 0.4, seed: 7, corr: 0.3 });
        engine.set_event_clock(true);
        let mut bank: Vec<Matrix> = (0..M)
            .map(|i| Matrix::from_fn(2, 4, |r, c| ((i * 31 + r * 7 + c) % 97) as f64))
            .collect();
        engine.mix_rounds(&mut bank, 12).unwrap();

        // The chaos path stays sparse too: restricted live-set mixing
        // (scattered single-node crashes keep the degree-2 ring
        // connected — node i-1 still reaches i+1 directly).
        if name == "ring" {
            let live: Vec<bool> = (0..M).map(|i| i % 97 != 0).collect();
            let restricted = MixingMatrix::build_restricted(&topo, &live).unwrap();
            assert!(
                restricted.nnz() < M * M / 8,
                "{name}: restricted mixing state is not sparse"
            );
        }

        TRACKING.store(false, Ordering::Relaxed);
        let largest = LARGEST.load(Ordering::Relaxed);
        assert!(
            engine.simulated_seconds() > 0.0,
            "{name}: the event clock never advanced"
        );
        assert!(
            largest < DENSE_BANK,
            "{name}: a {largest}-byte allocation is dense-bank sized (>= {DENSE_BANK})"
        );
        assert!(
            largest <= SPARSE_CEILING,
            "{name}: largest allocation {largest} exceeds the sparse ceiling {SPARSE_CEILING}"
        );
    }
}
