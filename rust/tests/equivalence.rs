//! Integration: the paper's centralized-equivalence claim (E6).
//!
//! Exercises the *public* API end to end: dataset generation → sharding →
//! decentralized training over a gossip network → comparison against the
//! centralized trainer on the pooled data.

use dssfn::admm::{solve_centralized, solve_decentralized, AdmmParams, Consensus, LayerLocalSolver};
use dssfn::coordinator::{ConsensusMode, DecentralizedTrainer, TrainOptions};
use dssfn::data::{shard_uniform, SynthClassification};
use dssfn::linalg::Matrix;
use dssfn::network::{CommLedger, GossipEngine, LatencyModel, MixingMatrix, Topology, WeightRule};
use dssfn::ssfn::{CentralizedTrainer, SsfnArchitecture, TrainHyper};
use dssfn::testing::property;
use std::sync::Arc;

fn task(p: usize, q: usize, j: usize, seed: u64) -> dssfn::data::ClassificationTask {
    let mut s = SynthClassification::with_shape("eqv", p, q, j, j / 2);
    s.class_sep = 2.5;
    s.noise = 0.8;
    s.seed = seed;
    s.generate().unwrap()
}

#[test]
fn single_layer_solve_equivalence_property() {
    // For random shapes, shard counts and μ: decentralized consensus ADMM
    // over shards == centralized ADMM on the pooled data (same convex
    // problem, K large enough for both to converge).
    property("layer solve centralized equivalence", 8, |g| {
        let n = g.usize_in(4, 14);
        let q = g.usize_in(2, 4);
        let j = g.usize_in(30, 60);
        let m = g.usize_in(2, 5);
        let mu = *g.choose(&[0.5, 1.0, 2.0]);
        let y = g.matrix(n, j, 1.0);
        let t = g.matrix(q, j, 1.0);
        let eps = 2.0 * q as f64;
        let params = AdmmParams { mu, eps, iterations: 1200 };
        let (central, _) = solve_centralized(&y, &t, &params).unwrap();
        let per = j / m;
        let solvers: Vec<LayerLocalSolver> = (0..m)
            .map(|i| {
                let c1 = if i == m - 1 { j } else { (i + 1) * per };
                LayerLocalSolver::new(
                    &y.col_block(i * per, c1).unwrap(),
                    &t.col_block(i * per, c1).unwrap(),
                    mu,
                )
                .unwrap()
            })
            .collect();
        let sol = solve_decentralized(&solvers, q, n, &params, &Consensus::Exact).unwrap();
        let diff = sol.output().max_abs_diff(&central);
        assert!(diff < 5e-3, "diff {diff} at n={n} q={q} j={j} m={m} mu={mu}");
    });
}

#[test]
fn gossip_solution_matches_exact_average_solution() {
    property("gossip == exact averaging", 4, |g| {
        let n = g.usize_in(5, 10);
        let q = g.usize_in(2, 3);
        let m = g.usize_in(3, 6);
        let j = m * g.usize_in(8, 15);
        let d = g.usize_in(1, m / 2);
        let y = g.matrix(n, j, 1.0);
        let t = g.matrix(q, j, 1.0);
        let params = AdmmParams { mu: 1.0, eps: 2.0 * q as f64, iterations: 80 };
        let per = j / m;
        let solvers: Vec<LayerLocalSolver> = (0..m)
            .map(|i| {
                LayerLocalSolver::new(
                    &y.col_block(i * per, (i + 1) * per).unwrap(),
                    &t.col_block(i * per, (i + 1) * per).unwrap(),
                    params.mu,
                )
                .unwrap()
            })
            .collect();
        let exact = solve_decentralized(&solvers, q, n, &params, &Consensus::Exact).unwrap();
        let mix = MixingMatrix::build(
            &Topology::Circular { nodes: m, degree: d.max(1) },
            WeightRule::EqualNeighbor,
        )
        .unwrap();
        let engine =
            GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default());
        let gossip = solve_decentralized(
            &solvers,
            q,
            n,
            &params,
            &Consensus::Gossip { engine: &engine, delta: 1e-11 },
        )
        .unwrap();
        let diff = gossip.output().max_abs_diff(exact.output());
        assert!(diff < 1e-6, "gossip deviates {diff} (m={m}, d={d})");
        assert!(gossip.max_disagreement() < 1e-7);
    });
}

#[test]
fn full_training_performance_equivalence() {
    // Table-II sense: same data, same seed — decentralized training over a
    // sparse ring must match centralized accuracy within noise.
    let t = task(10, 3, 180, 42);
    let arch = SsfnArchitecture {
        input_dim: 10,
        num_classes: 3,
        hidden: 2 * 3 + 40,
        layers: 4,
    };
    let hyper = TrainHyper {
        mu0: 1e-2,
        mul: 1.0,
        admm_iterations: 100, // the paper's K
        eps: None,
    };
    let (_, cr) = CentralizedTrainer::new(arch, hyper, 7)
        .unwrap()
        .train(&t)
        .unwrap();
    let opts = TrainOptions {
        nodes: 6,
        topology: Topology::Circular { nodes: 6, degree: 1 },
        weight_rule: WeightRule::EqualNeighbor,
        consensus: ConsensusMode::Gossip { delta: 1e-9 },
        latency: LatencyModel::default(),
        threads: 0,
        record_cost_curve: true,
    };
    let (_, dr) = DecentralizedTrainer::new(arch, hyper, opts, 7)
        .unwrap()
        .train_task(&t)
        .unwrap();
    assert!(
        (cr.train_accuracy - dr.train_accuracy).abs() < 0.06,
        "train {} vs {}",
        cr.train_accuracy,
        dr.train_accuracy
    );
    assert!(
        (cr.test_accuracy - dr.test_accuracy).abs() < 0.08,
        "test {} vs {}",
        cr.test_accuracy,
        dr.test_accuracy
    );
    // The decentralized run actually used the network.
    assert!(dr.comm_total.bytes > 0);
    // And per-layer objective trajectories agree relative to the
    // problem's scale (layer-0 cost). At the paper's K=100 the consensus
    // dual has not fully converged when the ε constraint is active (see
    // examples/conv_probe2), and deep layers sit at near-zero cost where
    // relative gaps are meaningless — the tight-K machine-ε regime is
    // covered by single_layer_solve_equivalence_property above and the
    // equivalence bench.
    let scale = cr.layers[0].final_cost().unwrap();
    for (cl, dl) in cr.layers.iter().zip(&dr.layers) {
        let (a, b) = (cl.final_cost().unwrap(), dl.final_cost().unwrap());
        assert!(
            (a - b).abs() <= 0.15 * a.max(1e-9) + 0.01 * scale,
            "layer {}: {a} vs {b} (scale {scale})",
            cl.layer
        );
    }
}

#[test]
fn disagreement_shrinks_with_tighter_delta() {
    let t = task(8, 3, 120, 9);
    let arch = SsfnArchitecture {
        input_dim: 8,
        num_classes: 3,
        hidden: 2 * 3 + 24,
        layers: 2,
    };
    let hyper = TrainHyper { mu0: 1e-2, mul: 1.0, admm_iterations: 30, eps: None };
    let mut worst = Vec::new();
    for delta in [1e-3, 1e-10] {
        let opts = TrainOptions {
            nodes: 5,
            topology: Topology::Circular { nodes: 5, degree: 1 },
            weight_rule: WeightRule::EqualNeighbor,
            consensus: ConsensusMode::Gossip { delta },
            latency: LatencyModel::default(),
            threads: 0,
            record_cost_curve: false,
        };
        let (_, r) = DecentralizedTrainer::new(arch, hyper, opts, 3)
            .unwrap()
            .train_task(&t)
            .unwrap();
        worst.push(
            r.layers
                .iter()
                .map(|l| l.consensus_disagreement)
                .fold(0.0f64, f64::max),
        );
    }
    assert!(
        worst[1] < worst[0] / 10.0,
        "tighter delta should shrink disagreement: {worst:?}"
    );
}

#[test]
fn equivalence_insensitive_to_shard_imbalance() {
    // Uneven shards via the public sharding API.
    let t = task(8, 3, 150, 11);
    let shards = shard_uniform(&t.train, 5).unwrap();
    let total: usize = shards.iter().map(|s| s.num_samples()).sum();
    assert_eq!(total, 150);
    // Pool back and compare against a weighted re-shard.
    let weighted = dssfn::data::shard_weighted(&t.train, &[5.0, 1.0, 1.0, 1.0, 2.0]).unwrap();
    let params = AdmmParams { mu: 1.0, eps: 6.0, iterations: 900 };
    let mk = |sh: &[dssfn::data::Dataset]| -> Matrix {
        let solvers: Vec<LayerLocalSolver> = sh
            .iter()
            .map(|s| LayerLocalSolver::new(&s.x, &s.t, params.mu).unwrap())
            .collect();
        solve_decentralized(&solvers, 3, 8, &params, &Consensus::Exact)
            .unwrap()
            .output()
            .clone()
    };
    let a = mk(&shards);
    let b = mk(&weighted);
    let diff = a.max_abs_diff(&b);
    assert!(diff < 5e-3, "shard-layout sensitivity: {diff}");
}
