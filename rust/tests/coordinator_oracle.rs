//! The coordinator's threaded `for_each_node` path must produce
//! **bit-identical** output to the sequential `solve_decentralized`
//! oracle — the promise made in `coordinator/mod.rs`'s docs. This test
//! replays the trainer's full per-layer pipeline (shard → prepare →
//! gossip-ADMM → weight build → forward → final solve) with the oracle
//! primitives on a single thread, then trains the real coordinator with
//! a thread budget that exercises both the node fan-out *and* the
//! intra-node threaded Gram build (`M < threads`), and compares every
//! learned matrix with `max_abs_diff == 0.0`.

use dssfn::admm::{solve_decentralized, Consensus, LayerLocalSolver};
use dssfn::coordinator::{resume_session, Checkpoint, ConsensusMode, DecentralizedTrainer, TrainOptions};
use dssfn::data::{shard_uniform, ClassificationTask, SynthClassification};
use dssfn::linalg::Matrix;
use dssfn::network::{
    CommLedger, GossipEngine, LatencyModel, MixingMatrix, Topology, WeightRule,
};
use dssfn::runtime::{ComputeBackend, NativeBackend};
use dssfn::session::StepEvent;
use dssfn::ssfn::{build_weight, RandomMatrices, SsfnArchitecture, TrainHyper};
use std::sync::Arc;

const SEED: u64 = 5;
const NODES: usize = 2;
const DEGREE: usize = 1;
const DELTA: f64 = 1e-9;

fn toy_task() -> ClassificationTask {
    let mut s = SynthClassification::with_shape("oracle-toy", 8, 3, 120, 60);
    s.class_sep = 3.0;
    s.noise = 0.6;
    s.generate().unwrap()
}

fn arch() -> SsfnArchitecture {
    SsfnArchitecture {
        input_dim: 8,
        num_classes: 3,
        // ≥ 64 so the hidden-layer Gram actually takes the threaded
        // syrk path when the coordinator hands it leftover threads.
        hidden: 2 * 3 + 60,
        layers: 1,
    }
}

fn hyper() -> TrainHyper {
    TrainHyper {
        mu0: 1e-2,
        mul: 1.0,
        admm_iterations: 30,
        eps: None,
    }
}

fn gossip_engine() -> GossipEngine {
    let mix = MixingMatrix::build(
        &Topology::Circular { nodes: NODES, degree: DEGREE },
        WeightRule::EqualNeighbor,
    )
    .unwrap();
    GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default())
}

/// Replay the trainer's layer pipeline with the sequential oracle
/// primitives: returns (W_1 of node 0, final consensus output Z).
fn oracle_pipeline(task: &ClassificationTask) -> (Matrix, Matrix) {
    let arch = arch();
    let hyper = hyper();
    let q = arch.num_classes;
    let backend = NativeBackend::new(); // intra hint left at 1: must not matter
    let shards = shard_uniform(&task.train, NODES).unwrap();
    let random = RandomMatrices::generate(&arch, SEED).unwrap();
    let engine = gossip_engine();

    // Layer 0: solve on the raw shard inputs.
    let mut ys: Vec<Matrix> = shards.iter().map(|s| s.x.clone()).collect();
    let params0 = hyper.admm_params(0, q);
    let solvers0: Vec<LayerLocalSolver> = (0..NODES)
        .map(|i| LayerLocalSolver::new(&ys[i], &shards[i].t, params0.mu).unwrap())
        .collect();
    let sol0 = solve_decentralized(
        &solvers0,
        q,
        ys[0].rows(),
        &params0,
        &Consensus::Gossip { engine: &engine, delta: DELTA },
    )
    .unwrap();

    // Advance: W_1 = [V_Q Z_m ; R_1] per node, forward through ReLU.
    let r1 = random.layer(1);
    let ws: Vec<Matrix> = sol0
        .states
        .iter()
        .map(|st| build_weight(&st.z, r1).unwrap())
        .collect();
    for (y, w) in ys.iter_mut().zip(&ws) {
        *y = backend.layer_forward(w, y).unwrap();
    }

    // Layer 1 (output layer): solve on the advanced features.
    let params1 = hyper.admm_params(1, q);
    let solvers1: Vec<LayerLocalSolver> = (0..NODES)
        .map(|i| LayerLocalSolver::new(&ys[i], &shards[i].t, params1.mu).unwrap())
        .collect();
    let sol1 = solve_decentralized(
        &solvers1,
        q,
        ys[0].rows(),
        &params1,
        &Consensus::Gossip { engine: &engine, delta: DELTA },
    )
    .unwrap();

    (ws.into_iter().next().unwrap(), sol1.output().clone())
}

#[test]
fn threaded_coordinator_bit_identical_to_sequential_oracle() {
    let task = toy_task();
    let (oracle_w1, oracle_z) = oracle_pipeline(&task);

    // threads=4 over NODES=2 ⇒ node_threads=2, intra_threads=2: both the
    // node fan-out and the threaded per-node Gram build are live.
    let opts = TrainOptions {
        nodes: NODES,
        topology: Topology::Circular { nodes: NODES, degree: DEGREE },
        weight_rule: WeightRule::EqualNeighbor,
        consensus: ConsensusMode::Gossip { delta: DELTA },
        latency: LatencyModel::default(),
        threads: 4,
        record_cost_curve: true,
    };
    let trainer = DecentralizedTrainer::new(arch(), hyper(), opts, SEED).unwrap();
    let (model, _report) = trainer.train_task(&task).unwrap();

    assert_eq!(model.weights().len(), 1);
    let w_diff = model.weights()[0].max_abs_diff(&oracle_w1);
    assert_eq!(w_diff, 0.0, "W_1 drifted from the sequential oracle");
    let z_diff = model.output().max_abs_diff(&oracle_z);
    assert_eq!(z_diff, 0.0, "output Z drifted from the sequential oracle");
}

fn two_layer_trainer() -> DecentralizedTrainer {
    let arch = SsfnArchitecture { layers: 2, ..arch() };
    let opts = TrainOptions {
        nodes: NODES,
        topology: Topology::Circular { nodes: NODES, degree: DEGREE },
        weight_rule: WeightRule::EqualNeighbor,
        consensus: ConsensusMode::Gossip { delta: DELTA },
        latency: LatencyModel::default(),
        threads: 4,
        record_cost_curve: true,
    };
    DecentralizedTrainer::new(arch, hyper(), opts, SEED).unwrap()
}

/// The tentpole resumability claim: a session checkpointed mid-layer,
/// serialized to bytes, restored and run to completion is bit-identical
/// to the uninterrupted one-shot `train_task` — every learned matrix,
/// the full cost curve, and the communication ledger agree exactly.
#[test]
fn mid_layer_checkpoint_resumes_bit_identical_to_one_shot() {
    let task = toy_task();
    let trainer = two_layer_trainer();
    let (one_model, one_report) = trainer.train_task(&task).unwrap();

    // Drive a fresh session until iteration 6 of layer 1 has completed,
    // snapshot (the machine is about to run iteration 7), serialize,
    // abandon the session entirely.
    let mut session = trainer.session(&task).unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::AdmmIteration { layer: 1, iteration: 6, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before the checkpoint point"),
        }
    };
    assert_eq!(ck.layer(), 1);
    assert_eq!(ck.iteration(), Some(7));
    assert_eq!(ck.layers_completed(), 1);
    let bytes = ck.to_bytes();
    drop(session);

    // Restore from the serialized bytes and run to completion.
    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.weights().len(), one_model.weights().len());
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0, "restored weight drifted");
    }
    assert_eq!(
        model.output().max_abs_diff(one_model.output()),
        0.0,
        "restored output drifted"
    );
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
    assert_eq!(report.total_gossip_rounds(), one_report.total_gossip_rounds());
    assert_eq!(report.layers.len(), one_report.layers.len());
    for (a, b) in report.layers.iter().zip(&one_report.layers) {
        assert_eq!(a.consensus_disagreement, b.consensus_disagreement);
        assert_eq!(a.comm, b.comm);
        assert_eq!(a.gossip_rounds, b.gossip_rounds);
    }
    assert_eq!(report.train_accuracy, one_report.train_accuracy);
    assert_eq!(report.test_accuracy, one_report.test_accuracy);
}

/// Same claim at a layer boundary: a checkpoint taken right after a
/// layer advanced (the machine is about to prepare the next layer)
/// restores with no transient state and still matches bit-identically.
#[test]
fn layer_boundary_checkpoint_resumes_bit_identically() {
    let task = toy_task();
    let trainer = two_layer_trainer();
    let (one_model, one_report) = trainer.train_task(&task).unwrap();

    let mut session = trainer.session(&task).unwrap();
    let ck = loop {
        match session.step().unwrap() {
            Some(StepEvent::LayerAdvanced { layer: 0, .. }) => {
                break session.checkpoint().unwrap();
            }
            Some(_) => {}
            None => panic!("session finished before layer 0 advanced"),
        }
    };
    assert_eq!(ck.layer(), 1);
    assert_eq!(ck.iteration(), None);
    drop(session);

    let ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();
    assert_eq!(model.output().max_abs_diff(one_model.output()), 0.0);
    for (a, b) in model.weights().iter().zip(one_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(report.full_cost_curve(), one_report.full_cost_curve());
    assert_eq!(report.comm_total, one_report.comm_total);
}

/// Restore validates the supplied task against the checkpoint's
/// fingerprint instead of silently training on the wrong data.
#[test]
fn restore_rejects_mismatched_task() {
    let task = toy_task();
    let trainer = two_layer_trainer();
    let mut session = trainer.session(&task).unwrap();
    session.step().unwrap();
    let ck = session.checkpoint().unwrap();
    let mut other = SynthClassification::with_shape("other-task", 8, 3, 120, 60);
    other.class_sep = 3.0;
    let other_task = other.generate().unwrap();
    assert!(resume_session(&ck, &other_task).is_err());

    // Same name, same shape, *different data* (different generator
    // knobs) — the content checksum must catch it.
    let mut imposter = SynthClassification::with_shape("oracle-toy", 8, 3, 120, 60);
    imposter.class_sep = 3.0;
    imposter.noise = 0.3;
    let imposter_task = imposter.generate().unwrap();
    assert!(resume_session(&ck, &imposter_task).is_err());
}

#[test]
fn exact_consensus_coordinator_matches_oracle_too() {
    let task = toy_task();
    let arch = arch();
    let hyper = hyper();
    let q = arch.num_classes;

    // Oracle, exact averaging, single thread.
    let shards = shard_uniform(&task.train, NODES).unwrap();
    let params0 = hyper.admm_params(0, q);
    let solvers0: Vec<LayerLocalSolver> = (0..NODES)
        .map(|i| LayerLocalSolver::new(&shards[i].x, &shards[i].t, params0.mu).unwrap())
        .collect();
    let sol0 = solve_decentralized(
        &solvers0,
        q,
        shards[0].x.rows(),
        &params0,
        &Consensus::Exact,
    )
    .unwrap();

    // Coordinator with the same consensus mode and a saturating thread
    // budget; replay only layer 0's Z via the learned W_1 relationship.
    let opts = TrainOptions {
        nodes: NODES,
        topology: Topology::Circular { nodes: NODES, degree: DEGREE },
        weight_rule: WeightRule::EqualNeighbor,
        consensus: ConsensusMode::Exact,
        latency: LatencyModel::default(),
        threads: 8,
        record_cost_curve: false,
    };
    let trainer = DecentralizedTrainer::new(arch, hyper, opts, SEED).unwrap();
    let (model, _) = trainer.train_task(&task).unwrap();
    let random = RandomMatrices::generate(&arch, SEED).unwrap();
    let expected_w1 = build_weight(&sol0.states[0].z, random.layer(1)).unwrap();
    assert_eq!(model.weights()[0].max_abs_diff(&expected_w1), 0.0);
}
