//! Integration: seeded fault injection through the full session stack.
//!
//! Pins the two ISSUE acceptance invariants:
//!
//! 1. **Zero-fault oracle** — a run whose `ChaosPlan` is enabled but
//!    never fires is bit-identical (weights, cost curve, bytes,
//!    simulated seconds) to the same run on the unwrapped fabric.
//! 2. **Mid-outage resume** — a checkpoint taken while a node is down
//!    resumes bit-identically: same final model, same report, same
//!    churn schedule.

use std::sync::Arc;

use dssfn::data::lookup;
use dssfn::network::{ChaosConfig, ChaosPlan};
use dssfn::session::SessionBuilder;
use dssfn::{resume_session, Checkpoint, StepEvent};

/// Quickstart task shared between runs so data generation cannot differ.
fn task(seed: u64) -> Arc<dssfn::data::ClassificationTask> {
    Arc::new(lookup("quickstart").unwrap().generator(seed).generate().unwrap())
}

fn is_churn_event(ev: &StepEvent) -> bool {
    matches!(
        ev,
        StepEvent::NodeDropped { .. }
            | StepEvent::NodeRejoined { .. }
            | StepEvent::QuorumStalled { .. }
    )
}

#[test]
fn zero_fault_chaos_session_matches_the_unwrapped_run_bit_for_bit() {
    // Find a chaos seed whose stream fires no crash in the first 256
    // membership steps at this (tiny but nonzero) crash probability:
    // the plan is *enabled*, so every averaging call runs the full
    // chaos path — membership step, quorum gate, catch-up scan — yet
    // no fault ever triggers. The run must be indistinguishable from
    // the unwrapped fabric down to the last bit.
    let m = 4;
    let crash_p = 1e-12;
    let mut chosen = None;
    'seed: for seed in 0..64u64 {
        let cfg = ChaosConfig { crash_p, rejoin_p: 0.0, seed, min_nodes: 1 };
        let plan = ChaosPlan::new(cfg).unwrap();
        for cursor in 0..256 {
            let mut live = vec![true; m];
            if !plan.step(cursor, &mut live).crashed.is_empty() {
                continue 'seed;
            }
        }
        chosen = Some(cfg);
        break;
    }
    let chaos = chosen.expect("no fault-free chaos seed in 0..64");

    let task = task(3);
    let run = |chaos_cfg: Option<ChaosConfig>| {
        let mut b = SessionBuilder::new()
            .shared_task(Arc::clone(&task))
            .seed(3)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(6)
            .nodes(m)
            .degree(2)
            .threads(1);
        if let Some(c) = chaos_cfg {
            b = b.chaos(c);
        }
        let mut session = b.build().unwrap();
        let mut churn = 0usize;
        while let Some(ev) = session.step().unwrap() {
            if is_churn_event(&ev) {
                churn += 1;
            }
        }
        let (model, report) = session.finish().unwrap();
        (model.into_ssfn().unwrap(), report, churn)
    };

    let (m_plain, r_plain, churn_plain) = run(None);
    let (m_chaos, r_chaos, churn_chaos) = run(Some(chaos));

    assert_eq!(churn_plain, 0);
    assert_eq!(churn_chaos, 0, "the zero-fault plan fired a churn event");
    assert_eq!(m_plain.weights().len(), m_chaos.weights().len());
    for (a, b) in m_plain.weights().iter().zip(m_chaos.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(m_plain.output().max_abs_diff(m_chaos.output()), 0.0);
    assert_eq!(r_plain.full_cost_curve(), r_chaos.full_cost_curve());
    assert_eq!(r_plain.comm_total, r_chaos.comm_total);
    assert_eq!(
        r_plain.simulated_comm_secs.to_bits(),
        r_chaos.simulated_comm_secs.to_bits()
    );
    // The chaos run still declares itself in the mode string.
    assert!(r_chaos.mode.contains("chaos(p="), "mode: {}", r_chaos.mode);
    assert!(!r_plain.mode.contains("chaos"), "mode: {}", r_plain.mode);
}

#[test]
fn mid_outage_checkpoint_resumes_bit_identically() {
    let task = task(5);
    let cfg = ChaosConfig { crash_p: 0.3, rejoin_p: 0.6, seed: 9, min_nodes: 2 };
    // Degree 2 on 4 nodes is the complete graph: no crash pattern can
    // disconnect the live set, so the run never errors on topology.
    let build = || {
        SessionBuilder::new()
            .shared_task(Arc::clone(&task))
            .seed(5)
            .layers(2)
            .hidden_extra(12)
            .admm_iterations(6)
            .nodes(4)
            .degree(2)
            .threads(1)
            .chaos(cfg)
            .build()
            .unwrap()
    };

    // Reference: one uninterrupted run.
    let mut reference = build();
    let mut churn = 0usize;
    while let Some(ev) = reference.step().unwrap() {
        if is_churn_event(&ev) {
            churn += 1;
        }
    }
    assert!(churn > 0, "crash_p = 0.3 over 12 calls produced no churn");
    let (ref_model, ref_report) = reference.finish().unwrap();
    let ref_model = ref_model.into_ssfn().unwrap();

    // Interrupted run: checkpoint at the first step boundary where some
    // node is down (mid-outage), serialize, drop, resume, finish.
    let mut session = build();
    let mut ck_bytes = None;
    while let Some(ev) = session.step().unwrap() {
        if matches!(ev, StepEvent::NodeDropped { .. }) {
            let ck = session.checkpoint().unwrap();
            if ck.chaos_liveness().iter().any(|&l| !l) {
                ck_bytes = Some(ck.to_bytes());
                break;
            }
        }
    }
    let bytes = ck_bytes.expect("no mid-outage step boundary before the run finished");
    drop(session);

    let ck = Checkpoint::from_bytes(&bytes).unwrap();
    assert!(
        ck.chaos_liveness().iter().any(|&l| !l),
        "snapshot did not land mid-outage"
    );
    assert!(ck.comm_config().chaos.enabled());
    let mut resumed = resume_session(&ck, &task).unwrap();
    let (model, report) = resumed.finish().unwrap();
    let model = model.into_ssfn().unwrap();

    assert_eq!(model.weights().len(), ref_model.weights().len());
    for (a, b) in model.weights().iter().zip(ref_model.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(model.output().max_abs_diff(ref_model.output()), 0.0);
    assert_eq!(report.full_cost_curve(), ref_report.full_cost_curve());
    assert_eq!(report.comm_total, ref_report.comm_total);
    assert_eq!(
        report.simulated_comm_secs.to_bits(),
        ref_report.simulated_comm_secs.to_bits()
    );
    assert_eq!(report.train_accuracy, ref_report.train_accuracy);
}

#[test]
fn churn_degrades_gracefully_and_charges_for_recovery() {
    // Same run at increasing crash probability: sim-time and stall
    // exposure must not shrink, and a mild churn rate must not wreck
    // the model (rejoin catch-up keeps the live set coherent).
    let task = task(7);
    let run = |crash_p: f64| {
        let mut b = SessionBuilder::new()
            .shared_task(Arc::clone(&task))
            .seed(7)
            .layers(1)
            .hidden_extra(12)
            .admm_iterations(8)
            .nodes(4)
            .degree(2)
            .threads(1);
        if crash_p > 0.0 {
            b = b.chaos(ChaosConfig {
                crash_p,
                rejoin_p: 0.7,
                seed: 21,
                min_nodes: 1,
            });
        }
        let mut session = b.build().unwrap();
        while session.step().unwrap().is_some() {}
        let (_, report) = session.finish().unwrap();
        report
    };
    let fault_free = run(0.0);
    let mild = run(0.05);
    let heavy = run(0.3);
    assert!(mild.simulated_comm_secs >= fault_free.simulated_comm_secs);
    assert!(heavy.simulated_comm_secs >= mild.simulated_comm_secs);
    // Mild churn stays within 5% of the fault-free final cost.
    let c0 = fault_free.final_cost().unwrap();
    let c1 = mild.final_cost().unwrap();
    assert!(
        (c1 - c0).abs() <= 0.05 * c0.abs().max(1e-12),
        "mild churn final cost {c1} strays >5% from fault-free {c0}"
    );
}
