//! Integration: the wire transport (`serve`/`worker`) over the
//! in-process loopback.
//!
//! The determinism bar: a fault-free loopback run — full framing,
//! handshake, rendezvous, per-round barrier — must be *bit-identical*
//! to the in-process synchronous run at the same seed. Plus the
//! checkpoint-fuzz-style hostility suite for the frame/message codec
//! (truncation at every byte, hostile length prefixes, seeded
//! bit-flips: always a clean `Err`, never a panic or an unbounded
//! allocation), and the crash/rejoin semantics driven through real
//! worker reactors.

use dssfn::config::ExperimentConfig;
use dssfn::linalg::Matrix;
use dssfn::session::{StepEvent, TrainSession};
use dssfn::transport::{
    duplex, frame, run_worker_with, wire, Conn, LoopbackListener, Message, ServeAlgorithm,
    ServeOptions, WorkerOptions, WorkerSummary, PROTOCOL_VERSION,
};
use dssfn::util::{Rng, SplitMix64};
use dssfn::{Error, Result};
use std::cell::RefCell;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

fn toy_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::named_dataset("quickstart").unwrap();
    cfg.seed = 0xBEEF;
    cfg.nodes = 4;
    cfg.degree = 1;
    cfg.layers = 2;
    cfg.admm_iterations = 6;
    cfg
}

/// A connect factory that hands out one pre-pushed loopback pair, then
/// errors (the fault-free tests never reconnect).
fn one_shot(listener: &LoopbackListener) -> impl FnMut() -> Result<Box<dyn Conn>> {
    let (server_end, worker_end) = duplex();
    listener.push(Box::new(server_end));
    let mut end = Some(worker_end);
    move || {
        end.take()
            .map(|e| Box::new(e) as Box<dyn Conn>)
            .ok_or_else(|| Error::Network("one-shot loopback conn already used".into()))
    }
}

/// The determinism bar, parameterised over the comm schedule: run the
/// same config in-process (reference) and as serve + M loopback worker
/// reactors, and assert the two runs are *bit-identical* — weights,
/// output, cost curve, headline metrics, simulated ledger.
fn assert_loopback_matches_in_process(cfg: &ExperimentConfig) {
    // Reference: the ordinary in-process run over the same phase machine.
    let session = cfg.session_builder().unwrap().build().unwrap();
    let (ref_model, ref_report) = session.run_to_completion().unwrap();
    let ref_model = ref_model.into_ssfn().unwrap();

    // Wire: one server, M worker reactors on threads, loopback pipes.
    let listener = LoopbackListener::new();
    let mut handles = Vec::new();
    for shard in 0..cfg.nodes {
        let connect = one_shot(&listener);
        let cfg_w = cfg.clone();
        handles.push(thread::spawn(move || {
            run_worker_with(
                &cfg_w,
                WorkerOptions {
                    shard,
                    ..WorkerOptions::default()
                },
                connect,
            )
        }));
    }
    let algo = ServeAlgorithm::new(cfg, Box::new(listener), ServeOptions::default()).unwrap();
    let session = TrainSession::from_algorithm(Box::new(algo));
    let (model, report) = session.run_to_completion().unwrap();
    let model = model.into_ssfn().unwrap();
    for h in handles {
        let summary = h.join().unwrap().unwrap();
        assert_eq!(summary.layers, report.layers.len());
    }

    // Bit-identical: weights, output, cost curve, headline metrics.
    assert_eq!(model.weights().len(), ref_model.weights().len());
    for (w, r) in model.weights().iter().zip(ref_model.weights()) {
        assert_eq!(w.max_abs_diff(r), 0.0);
    }
    assert_eq!(model.output().max_abs_diff(ref_model.output()), 0.0);
    assert_eq!(report.full_cost_curve(), ref_report.full_cost_curve());
    assert_eq!(
        report.test_accuracy.to_bits(),
        ref_report.test_accuracy.to_bits()
    );
    // Both sides charge the same simulated ledger (only consensus
    // averaging is billed; the wire itself is real, not simulated).
    assert_eq!(report.comm_total.bytes, ref_report.comm_total.bytes);
}

#[test]
fn loopback_run_is_bit_identical_to_in_process() {
    assert_loopback_matches_in_process(&toy_config());
}

#[test]
fn loopback_semisync_is_bit_identical_to_in_process() {
    let mut cfg = toy_config();
    cfg.schedule = "semisync".into(); // staleness defaults to s = 2
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn loopback_lossy_is_bit_identical_to_in_process() {
    let mut cfg = toy_config();
    cfg.schedule = "lossy".into(); // loss_p defaults to 0.1
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn loopback_adaptive_delta_is_bit_identical_to_in_process() {
    let mut cfg = toy_config();
    cfg.adaptive_delta = Some(1e-6);
    cfg.adaptive_period = 4; // plateaus may double the period: Hold frames
    cfg.record_cost_curve = true; // adaptive δ steers off the cost curve
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn loopback_iter_staleness_is_bit_identical_to_in_process() {
    let mut cfg = toy_config();
    cfg.iter_staleness = 2; // ADMM updates up to 2 iterations stale
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn loopback_compressed_is_bit_identical_to_in_process() {
    // The compressor lives inside the server's gossip engine (shares
    // are compressed before framing), so the compressed wire run —
    // dither draws, error-feedback residuals, compressed byte billing —
    // is bit-identical to the compressed in-process run.
    let mut cfg = toy_config();
    cfg.compress = Some("q4".into());
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn loopback_compressed_semisync_is_bit_identical_to_in_process() {
    let mut cfg = toy_config();
    cfg.compress = Some("topk:0.25".into());
    cfg.schedule = "semisync".into();
    assert_loopback_matches_in_process(&cfg);
}

#[test]
fn handshake_rejects_mismatches_cleanly() {
    let mut cfg = toy_config();
    cfg.nodes = 2;
    cfg.layers = 1;
    cfg.admm_iterations = 3;

    let listener = LoopbackListener::new();
    let l = listener.clone();
    let cfg_s = cfg.clone();
    let server = thread::spawn(move || -> Result<()> {
        let algo = ServeAlgorithm::new(&cfg_s, Box::new(l), ServeOptions::default())?;
        TrainSession::from_algorithm(Box::new(algo)).run_to_completion()?;
        Ok(())
    });

    // A future protocol version is named in the rejection.
    let (mut we, se) = duplex();
    listener.push(Box::new(se));
    let mut scratch = Vec::new();
    wire::send(
        &mut we,
        &mut scratch,
        &Message::Hello {
            protocol: PROTOCOL_VERSION + 1,
            shard: 0,
            nodes: 2,
            config_fp: 0,
            task_checksum: 0,
            schedule: "sync".into(),
            compression: "none".into(),
            have_layer: 0,
        },
    )
    .unwrap();
    match wire::recv(&mut we, &mut scratch).unwrap() {
        Message::Reject { reason } => {
            assert!(reason.contains("protocol version"), "{reason}")
        }
        other => panic!("expected Reject, got {other:?}"),
    }
    drop(we);

    // A different seed changes the config fingerprint: fatal, named.
    let mut bad = cfg.clone();
    bad.seed ^= 1;
    let err = run_worker_with(&bad, WorkerOptions::default(), one_shot(&listener)).unwrap_err();
    assert!(err.to_string().contains("config fingerprint"), "{err}");

    // A different cluster size is named before the fingerprint.
    let mut bad = cfg.clone();
    bad.nodes = 3;
    let err = run_worker_with(&bad, WorkerOptions::default(), one_shot(&listener)).unwrap_err();
    assert!(err.to_string().contains("cluster size"), "{err}");

    // A different comm schedule is named before the fingerprint (the
    // fingerprint also covers it, but the name beats an opaque hash).
    let mut bad = cfg.clone();
    bad.schedule = "semisync".into();
    let err = run_worker_with(&bad, WorkerOptions::default(), one_shot(&listener)).unwrap_err();
    assert!(err.to_string().contains("schedule mismatch"), "{err}");

    // So is a different gossip compressor: a q4 worker against this
    // uncompressed server is rejected by the knob's name.
    let mut bad = cfg.clone();
    bad.compress = Some("q4".into());
    let err = run_worker_with(&bad, WorkerOptions::default(), one_shot(&listener)).unwrap_err();
    assert!(err.to_string().contains("compression mismatch"), "{err}");

    // An out-of-range shard never even connects.
    let err = run_worker_with(
        &cfg,
        WorkerOptions {
            shard: 2,
            ..WorkerOptions::default()
        },
        || Err(Error::Network("must not connect".into())),
    )
    .unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");

    // The server survived every reject: real workers complete the run.
    let mut handles = Vec::new();
    for shard in 0..cfg.nodes {
        let connect = one_shot(&listener);
        let cfg_w = cfg.clone();
        handles.push(thread::spawn(move || {
            run_worker_with(
                &cfg_w,
                WorkerOptions {
                    shard,
                    ..WorkerOptions::default()
                },
                connect,
            )
        }));
    }
    server.join().unwrap().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn absent_worker_rejoins_via_catch_up() {
    let mut cfg = toy_config();
    cfg.nodes = 2;

    let listener = LoopbackListener::new();
    // Worker 0 is present from the start; shard 1 stays dark.
    let connect0 = one_shot(&listener);
    let cfg0 = cfg.clone();
    let worker0 = thread::spawn(move || {
        run_worker_with(
            &cfg0,
            WorkerOptions {
                shard: 0,
                ..WorkerOptions::default()
            },
            connect0,
        )
    });

    let events: RefCell<Vec<StepEvent>> = RefCell::new(Vec::new());
    let worker1: RefCell<Option<thread::JoinHandle<Result<WorkerSummary>>>> = RefCell::new(None);
    // With a quorum of 1, rendezvous proceeds with shard 1 treated as
    // crashed-from-the-start (restricted mixing over the live set).
    let algo = ServeAlgorithm::new(
        &cfg,
        Box::new(listener.clone()),
        ServeOptions {
            min_clients: 1,
            io_timeout: None,
        },
    )
    .unwrap();
    let mut session = TrainSession::from_algorithm(Box::new(algo));
    session.observe_fn(|ev| {
        events.borrow_mut().push(*ev);
        // Deterministic mid-run rejoin: once iteration 2 of layer 0 has
        // completed, shard 1 connects and is caught up by the server.
        if let StepEvent::AdmmIteration {
            layer: 0,
            iteration: 2,
            ..
        } = ev
        {
            if worker1.borrow().is_none() {
                let connect1 = one_shot(&listener);
                let cfg1 = cfg.clone();
                *worker1.borrow_mut() = Some(thread::spawn(move || {
                    run_worker_with(
                        &cfg1,
                        WorkerOptions {
                            shard: 1,
                            ..WorkerOptions::default()
                        },
                        connect1,
                    )
                }));
            }
        }
    });
    let (model, report) = session.finish().unwrap();
    drop(session);

    let summary0 = worker0.join().unwrap().unwrap();
    let summary1 = worker1
        .into_inner()
        .expect("rejoin never triggered")
        .join()
        .unwrap()
        .unwrap();
    assert_eq!(summary0.layers, report.layers.len());
    assert_eq!(summary1.layers, report.layers.len());

    let evs = events.into_inner();
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeDropped { node: 1, .. })),
        "missing NodeDropped for the absent shard"
    );
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeRejoined { node: 1, .. })),
        "missing NodeRejoined after the catch-up"
    );

    let model = model.into_ssfn().unwrap();
    assert_eq!(report.layers.len(), 2);
    assert!(report.test_accuracy.is_finite());
    assert!(model.output().frobenius_norm_sq().is_finite());
}

#[test]
fn late_joiner_at_layer_one_replays_the_weight_stack() {
    // A worker that first appears after layer 0 has advanced declares
    // `have_layer = 0` in its Hello, so the catch-up ships the full
    // weight stack (from_layer = 0) and the worker replays it through
    // its raw shard before adopting the layer-1 consensus share.
    let mut cfg = toy_config();
    cfg.nodes = 2;

    let listener = LoopbackListener::new();
    let connect0 = one_shot(&listener);
    let cfg0 = cfg.clone();
    let worker0 = thread::spawn(move || {
        run_worker_with(
            &cfg0,
            WorkerOptions {
                shard: 0,
                ..WorkerOptions::default()
            },
            connect0,
        )
    });

    let events: RefCell<Vec<StepEvent>> = RefCell::new(Vec::new());
    let worker1: RefCell<Option<thread::JoinHandle<Result<WorkerSummary>>>> = RefCell::new(None);
    let algo = ServeAlgorithm::new(
        &cfg,
        Box::new(listener.clone()),
        ServeOptions {
            min_clients: 1,
            io_timeout: None,
        },
    )
    .unwrap();
    let mut session = TrainSession::from_algorithm(Box::new(algo));
    session.observe_fn(|ev| {
        events.borrow_mut().push(*ev);
        if let StepEvent::AdmmIteration {
            layer: 1,
            iteration: 2,
            ..
        } = ev
        {
            if worker1.borrow().is_none() {
                let connect1 = one_shot(&listener);
                let cfg1 = cfg.clone();
                *worker1.borrow_mut() = Some(thread::spawn(move || {
                    run_worker_with(
                        &cfg1,
                        WorkerOptions {
                            shard: 1,
                            ..WorkerOptions::default()
                        },
                        connect1,
                    )
                }));
            }
        }
    });
    let (model, report) = session.finish().unwrap();
    drop(session);

    let summary0 = worker0.join().unwrap().unwrap();
    let summary1 = worker1
        .into_inner()
        .expect("rejoin never triggered")
        .join()
        .unwrap()
        .unwrap();
    assert_eq!(summary0.layers, report.layers.len());
    assert_eq!(summary1.layers, report.layers.len());

    let evs = events.into_inner();
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeDropped { node: 1, .. })),
        "missing NodeDropped for the absent shard"
    );
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeRejoined { node: 1, layer: 1, .. })),
        "missing NodeRejoined at layer 1"
    );

    let model = model.into_ssfn().unwrap();
    assert_eq!(report.layers.len(), 2);
    assert!(report.test_accuracy.is_finite());
    assert!(model.output().frobenius_norm_sq().is_finite());
}

/// A conn that starts failing every read and write once the shared kill
/// switch flips — a mid-run TCP drop, seen from the worker's side.
struct KillSwitch {
    inner: Box<dyn Conn>,
    dead: Arc<AtomicBool>,
}

impl KillSwitch {
    fn check(&self) -> std::io::Result<()> {
        if self.dead.load(Ordering::SeqCst) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "kill switch flipped",
            ));
        }
        Ok(())
    }
}

impl Read for KillSwitch {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        self.check()?;
        self.inner.read(buf)
    }
}

impl Write for KillSwitch {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.check()?;
        self.inner.write(buf)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

impl Conn for KillSwitch {}

/// A connect factory whose first conn is pre-pushed (visible to the
/// rendezvous) and killable; reconnects get fresh, reliable pipes.
fn flaky_then_fresh(
    listener: &LoopbackListener,
    dead: &Arc<AtomicBool>,
) -> impl FnMut() -> Result<Box<dyn Conn>> {
    let (server_end, worker_end) = duplex();
    listener.push(Box::new(server_end));
    let mut first = Some(Box::new(KillSwitch {
        inner: Box::new(worker_end),
        dead: Arc::clone(dead),
    }) as Box<dyn Conn>);
    let listener = listener.clone();
    move || match first.take() {
        Some(c) => Ok(c),
        None => {
            let (server_end, worker_end) = duplex();
            listener.push(Box::new(server_end));
            Ok(Box::new(worker_end) as Box<dyn Conn>)
        }
    }
}

#[test]
fn reconnect_after_layer_advance_catches_up_in_o1() {
    // A worker that crashes *after* advancing past layer 0 keeps its
    // layer-boundary snapshot (features embedding the first weight), so
    // its reconnect Hello declares `have_layer = 1` and the catch-up
    // ships an empty weight tail — the O(1) rejoin path.
    let mut cfg = toy_config();
    cfg.nodes = 2;

    let listener = LoopbackListener::new();
    let dead = Arc::new(AtomicBool::new(false));

    let connect0 = one_shot(&listener);
    let cfg0 = cfg.clone();
    let worker0 = thread::spawn(move || {
        run_worker_with(
            &cfg0,
            WorkerOptions {
                shard: 0,
                ..WorkerOptions::default()
            },
            connect0,
        )
    });
    let connect1 = flaky_then_fresh(&listener, &dead);
    let cfg1 = cfg.clone();
    let worker1 = thread::spawn(move || {
        run_worker_with(
            &cfg1,
            WorkerOptions {
                shard: 1,
                ..WorkerOptions::default()
            },
            connect1,
        )
    });

    let events: RefCell<Vec<StepEvent>> = RefCell::new(Vec::new());
    // Quorum of 1: the run survives the drop with restricted mixing
    // while the killed worker reconnects.
    let algo = ServeAlgorithm::new(
        &cfg,
        Box::new(listener),
        ServeOptions {
            min_clients: 1,
            io_timeout: None,
        },
    )
    .unwrap();
    let mut session = TrainSession::from_algorithm(Box::new(algo));
    session.observe_fn(|ev| {
        events.borrow_mut().push(*ev);
        // Once layer 1 is underway, worker 1's conn starts failing; its
        // next I/O errors and it reconnects with `have_layer = 1`.
        if let StepEvent::AdmmIteration {
            layer: 1,
            iteration: 0,
            ..
        } = ev
        {
            dead.store(true, Ordering::SeqCst);
        }
    });
    let (model, report) = session.finish().unwrap();
    drop(session);

    let summary0 = worker0.join().unwrap().unwrap();
    let summary1 = worker1.join().unwrap().unwrap();
    assert_eq!(summary0.layers, report.layers.len());
    assert_eq!(summary1.layers, report.layers.len());
    assert!(dead.load(Ordering::SeqCst), "kill switch never flipped");

    let evs = events.into_inner();
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeDropped { node: 1, layer: 1, .. })),
        "missing NodeDropped for the killed worker"
    );
    assert!(
        evs.iter()
            .any(|e| matches!(e, StepEvent::NodeRejoined { node: 1, layer: 1, .. })),
        "missing NodeRejoined after the O(1) catch-up"
    );

    let model = model.into_ssfn().unwrap();
    assert_eq!(report.layers.len(), 2);
    assert!(report.test_accuracy.is_finite());
    assert!(model.output().frobenius_norm_sq().is_finite());
}

// ---- frame/message hostility suite (checkpoint-fuzz style) ----

fn sample_messages() -> Vec<Message> {
    vec![
        Message::Hello {
            protocol: PROTOCOL_VERSION,
            shard: 3,
            nodes: 8,
            config_fp: 0x1234_5678_9abc_def0,
            task_checksum: 0x0fed_cba9_8765_4321,
            schedule: "semisync(s=2)".into(),
            compression: "q4".into(),
            have_layer: 1,
        },
        Message::Welcome {
            protocol: PROTOCOL_VERSION,
        },
        Message::Reject {
            reason: "config fingerprint mismatch".into(),
        },
        Message::Step {
            layer: 1,
            iteration: 7,
        },
        Message::Share {
            layer: 1,
            iteration: 7,
            s: Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f64 * 0.5 - 1.0),
        },
        Message::Mixed {
            layer: 1,
            iteration: 7,
            last_iter: true,
            s: Matrix::from_fn(2, 3, |r, c| (r + c) as f64),
        },
        Message::Cost {
            layer: 1,
            iteration: 7,
            cost: 42.25,
        },
        Message::CostProbe { layer: 1 },
        Message::Advance {
            layer: 1,
            last: false,
        },
        Message::Hold {
            layer: 1,
            iteration: 3,
        },
        Message::CatchUp {
            layer: 2,
            iteration: 5,
            from_layer: 1,
            weights: vec![Matrix::zeros(2, 2), Matrix::from_fn(1, 4, |_, c| c as f64)],
            s: Matrix::zeros(2, 3),
        },
    ]
}

fn encode_stream(msgs: &[Message]) -> Vec<u8> {
    let mut stream = Vec::new();
    let mut payload = Vec::new();
    for m in msgs {
        m.encode_into(&mut payload).unwrap();
        frame::write_frame(&mut stream, &payload).unwrap();
    }
    stream
}

/// Parse messages until the bytes run out (clean boundary) or a frame /
/// decode error. Returns how many full messages parsed and the outcome.
fn drain(mut bytes: &[u8]) -> (usize, Result<()>) {
    let mut buf = Vec::new();
    let mut n = 0;
    loop {
        if bytes.is_empty() {
            return (n, Ok(()));
        }
        match frame::read_frame(&mut bytes, &mut buf) {
            Ok(()) => match Message::decode(&buf) {
                Ok(_) => n += 1,
                Err(e) => return (n, Err(e)),
            },
            Err(e) => return (n, Err(e)),
        }
    }
}

#[test]
fn wire_stream_survives_truncation_at_every_byte() {
    let msgs = sample_messages();
    let stream = encode_stream(&msgs);
    let (n, res) = drain(&stream);
    assert_eq!(n, msgs.len());
    res.unwrap();
    for cut in 0..stream.len() {
        let (n, res) = drain(&stream[..cut]);
        // A truncated stream either errors or yields a clean strict
        // prefix of complete frames — never a panic, never a hang.
        assert!(
            res.is_err() || n < msgs.len(),
            "cut at {cut} parsed the full stream"
        );
    }
}

#[test]
fn wire_stream_survives_seeded_bitflips() {
    let msgs = sample_messages();
    let stream = encode_stream(&msgs);
    let mut rng = SplitMix64::new(0xF1_1F);
    for _ in 0..300 {
        let mut fuzzed = stream.clone();
        let pos = (rng.next_u64() as usize) % fuzzed.len();
        let bit = (rng.next_u64() % 8) as u8;
        fuzzed[pos] ^= 1 << bit;
        // Must not panic or allocate unboundedly; Err and float-payload
        // reinterpretation are both acceptable outcomes.
        let _ = drain(&fuzzed);
    }
}

#[test]
fn hostile_length_prefixes_are_rejected_without_allocation() {
    for len in [u64::MAX, frame::MAX_FRAME + 1, 1u64 << 60] {
        let mut bytes = len.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[0u8; 32]);
        let mut buf = Vec::new();
        let err = frame::read_frame(&mut &bytes[..], &mut buf).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert!(buf.capacity() < 1 << 20, "hostile prefix preallocated");
    }
}

/// A reader that trickles one byte per `read` call — frames must
/// reassemble across arbitrarily fragmented reads.
struct OneByte<'a>(&'a [u8]);

impl Read for OneByte<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.0.is_empty() || buf.is_empty() {
            return Ok(0);
        }
        buf[0] = self.0[0];
        self.0 = &self.0[1..];
        Ok(1)
    }
}

#[test]
fn frames_reassemble_from_one_byte_reads() {
    let msgs = sample_messages();
    let stream = encode_stream(&msgs);
    let mut r = OneByte(&stream);
    let mut buf = Vec::new();
    for m in &msgs {
        frame::read_frame(&mut r, &mut buf).unwrap();
        assert_eq!(&Message::decode(&buf).unwrap(), m);
    }
}
