//! Property-based invariants over the coordinator substrates (the
//! offline-build stand-in for `proptest`, see `dssfn::testing`).

use dssfn::data::{shard_uniform, shard_weighted, SynthClassification};
use dssfn::linalg::Matrix;
use dssfn::network::{
    CommLedger, CompressionConfig, Compressor, GossipEngine, LatencyModel, MixingMatrix,
    Topology, WeightRule,
};
use dssfn::session::SessionBuilder;
use dssfn::testing::property;
use std::sync::Arc;

#[test]
fn mixing_matrices_doubly_stochastic_on_random_topologies() {
    property("mixing doubly stochastic", 24, |g| {
        let m = g.usize_in(2, 24);
        let topo = if g.bool_with(0.5) {
            let dmax = Topology::max_circular_degree(m).max(1);
            Topology::Circular { nodes: m, degree: g.usize_in(1, dmax) }
        } else {
            Topology::RandomGeometric {
                nodes: m,
                radius: g.f64_in(0.15, 0.6),
                seed: g.case() as u64,
            }
        };
        let rule = match topo {
            Topology::Circular { .. } => WeightRule::EqualNeighbor,
            _ => WeightRule::Metropolis,
        };
        let mix = MixingMatrix::build(&topo, rule).unwrap();
        // validate() ran inside build; re-check the eigen bound here.
        assert!(mix.lambda2() < 1.0 + 1e-9, "λ2 = {}", mix.lambda2());
        // consensus_rounds must be monotone in delta.
        assert!(mix.consensus_rounds(1e-12) >= mix.consensus_rounds(1e-2));
    });
}

#[test]
fn gossip_preserves_sum_and_contracts() {
    property("gossip conservation + contraction", 16, |g| {
        let m = g.usize_in(3, 16);
        let dmax = Topology::max_circular_degree(m).max(1);
        let d = g.usize_in(1, dmax);
        let rows = g.usize_in(1, 6);
        let cols = g.usize_in(1, 6);
        let mix = MixingMatrix::build(
            &Topology::Circular { nodes: m, degree: d },
            WeightRule::EqualNeighbor,
        )
        .unwrap();
        let engine =
            GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default());
        let mut vals: Vec<Matrix> = (0..m).map(|_| g.matrix(rows, cols, 3.0)).collect();
        let avg = GossipEngine::exact_average(&vals).unwrap();
        let before: f64 = vals.iter().map(|v| v.as_slice().iter().sum::<f64>()).sum();
        let err0: f64 = vals
            .iter()
            .map(|v| v.max_abs_diff(&avg))
            .fold(0.0, f64::max);
        engine.mix_rounds(&mut vals, 12).unwrap();
        let after: f64 = vals.iter().map(|v| v.as_slice().iter().sum::<f64>()).sum();
        assert!(
            (before - after).abs() < 1e-8 * (1.0 + before.abs()),
            "sum drift"
        );
        let err1: f64 = vals
            .iter()
            .map(|v| v.max_abs_diff(&avg))
            .fold(0.0, f64::max);
        assert!(err1 <= err0 + 1e-12, "consensus error grew: {err0} -> {err1}");
    });
}

#[test]
fn sharding_partitions_every_sample_exactly_once() {
    property("shard partition", 16, |g| {
        let q = g.usize_in(2, 5);
        let j = g.usize_in(20, 120);
        let m = g.usize_in(1, j.min(12));
        let task = {
            let mut s = SynthClassification::with_shape("p", g.usize_in(2, 10), q, j, 10);
            s.seed = g.case() as u64;
            s.generate().unwrap()
        };
        let shards = if g.bool_with(0.5) {
            shard_uniform(&task.train, m).unwrap()
        } else {
            let w: Vec<f64> = (0..m).map(|_| g.f64_in(0.2, 3.0)).collect();
            shard_weighted(&task.train, &w).unwrap()
        };
        let total: usize = shards.iter().map(|s| s.num_samples()).sum();
        assert_eq!(total, j);
        // Column-exact reconstruction in order.
        let mut col = 0usize;
        for sh in &shards {
            for c in 0..sh.num_samples() {
                assert_eq!(sh.labels[c], task.train.labels[col]);
                for r in 0..task.train.input_dim() {
                    assert_eq!(sh.x.get(r, c), task.train.x.get(r, col));
                }
                col += 1;
            }
        }
    });
}

#[test]
fn frobenius_projection_is_projection() {
    property("P_eps is a metric projection", 32, |g| {
        let rows = g.usize_in(1, 8);
        let cols = g.usize_in(1, 8);
        let eps = g.f64_in(0.1, 10.0);
        let z = g.matrix(rows, cols, 4.0);
        let mut p = z.clone();
        p.project_frobenius(eps);
        // Feasible.
        assert!(p.frobenius_norm() <= eps + 1e-9);
        // Idempotent.
        let mut pp = p.clone();
        pp.project_frobenius(eps);
        assert!(pp.max_abs_diff(&p) < 1e-12);
        // Non-expansive toward any feasible point (here: scaled-down z).
        let mut feasible = z.clone();
        feasible.project_frobenius(eps * 0.5);
        let dz = z.sub(&feasible).unwrap().frobenius_norm();
        let dp = p.sub(&feasible).unwrap().frobenius_norm();
        assert!(dp <= dz + 1e-9);
    });
}

#[test]
fn cholesky_solve_residuals_bounded() {
    property("cholesky solves SPD systems", 24, |g| {
        let n = g.usize_in(1, 40);
        let ridge = g.f64_in(0.5, 5.0) + n as f64 * 0.1;
        let a = g.spd(n, ridge);
        let f = a.cholesky().unwrap();
        let x_true = g.matrix(3, n, 2.0);
        let b = x_true.matmul(&a).unwrap();
        let x = f.solve_xa(&b).unwrap();
        assert!(
            x.max_abs_diff(&x_true) < 1e-6,
            "n={n} err {}",
            x.max_abs_diff(&x_true)
        );
    });
}

#[test]
fn stochastic_quantizer_is_unbiased_at_every_bit_width() {
    // The dither draw picks round-up with probability equal to the
    // fractional level, so E[Q(v)] = v conditional on the scale. Check
    // the empirical mean over 10k independent dither draws per
    // bit-width (accumulators reset between draws so pure quantization
    // is measured, not error feedback). The first entry pins the scale
    // at 1.0 and quantizes exactly; the rest sit between levels for
    // every bit-width, so each draw genuinely dithers.
    let targets = [1.0, 0.37, -0.61, 0.083];
    let src = Matrix::from_fn(1, targets.len(), |_, c| targets[c]);
    for bits in 1..=8u8 {
        let comp = Compressor::new(CompressionConfig::Quantize { bits }, 0x5eed + bits as u64);
        let draws = 10_000u64;
        let mut sum = vec![0.0f64; targets.len()];
        for round in 0..draws {
            comp.reset();
            let (msg, _) = comp.compress(0, round, &src).unwrap();
            for (s, &m) in sum.iter_mut().zip(msg.as_slice()) {
                *s += m;
            }
        }
        for (i, (&t, s)) in targets.iter().zip(&sum).enumerate() {
            let mean = s / draws as f64;
            // Worst case (1 bit, v = 0.083): per-draw std < 1, so the
            // standard error of the mean is < 0.01 — 0.05 is 5σ.
            assert!(
                (mean - t).abs() < 0.05,
                "q{bits} entry {i}: mean {mean} vs target {t}"
            );
        }
    }
}

#[test]
fn top_k_split_conserves_every_element_bit_exactly() {
    property("top-k split is exact", 24, |g| {
        let rows = g.usize_in(1, 8);
        let cols = g.usize_in(1, 8);
        let n = rows * cols;
        let frac = g.f64_in(0.05, 0.95);
        let cfg = CompressionConfig::TopK { frac };
        let comp = Compressor::new(cfg, g.case() as u64);
        let k = cfg.kept(n);
        // Round 1: e = 0, so t = src.
        let src = g.matrix(rows, cols, 3.0);
        let (msg, err) = comp.compress(0, 0, &src).unwrap();
        let nz_src = src.as_slice().iter().filter(|v| **v != 0.0).count();
        let kept = msg.as_slice().iter().filter(|v| **v != 0.0).count();
        if nz_src >= k {
            assert_eq!(kept, k, "frac={frac} n={n}");
        } else {
            assert!(kept <= k);
        }
        for ((&m, &e), &t) in msg.as_slice().iter().zip(err.as_slice()).zip(src.as_slice()) {
            let conserved = (m.to_bits() == t.to_bits() && e == 0.0)
                || (e.to_bits() == t.to_bits() && m == 0.0);
            assert!(conserved, "lossy split: t={t} m={m} e={e}");
        }
        // Round 2: the accumulator is non-zero; the split must conserve
        // t = src2 + e bit-exactly all the same.
        let src2 = g.matrix(rows, cols, 3.0);
        let mut expect = src2.clone();
        expect.axpy(1.0, &err).unwrap();
        let (msg2, err2) = comp.compress(0, 1, &src2).unwrap();
        for ((&m, &e), &t) in msg2
            .as_slice()
            .iter()
            .zip(err2.as_slice())
            .zip(expect.as_slice())
        {
            let conserved = (m.to_bits() == t.to_bits() && e == 0.0)
                || (e.to_bits() == t.to_bits() && m == 0.0);
            assert!(conserved, "round-2 lossy split: t={t} m={m} e={e}");
        }
    });
}

#[test]
fn disabled_compression_is_bit_identical_through_the_session_stack() {
    // `--compress none` must run the exact pre-compression code path: a
    // session with compression explicitly disabled produces the same
    // model, curve and ledger bit-for-bit as one that never heard of
    // the knob.
    let builder = || {
        SessionBuilder::new()
            .dataset("quickstart")
            .seed(5)
            .layers(1)
            .hidden_extra(8)
            .admm_iterations(4)
            .nodes(4)
            .degree(1)
            .record_cost_curve(true)
            .threads(1)
    };
    let run = |b: SessionBuilder| -> dssfn::Result<_> {
        let mut session = b.build()?;
        while session.step()?.is_some() {}
        session.finish()
    };
    let (m_plain, r_plain) = run(builder()).unwrap();
    let (m_none, r_none) =
        run(builder().compression(CompressionConfig::parse("none").unwrap())).unwrap();
    assert_eq!(m_plain.weights().len(), m_none.weights().len());
    for (a, b) in m_plain.weights().iter().zip(m_none.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(r_plain.comm_total, r_none.comm_total);
    assert_eq!(
        r_plain.simulated_comm_secs.to_bits(),
        r_none.simulated_comm_secs.to_bits()
    );
}

#[test]
fn latency_model_monotonicity() {
    property("latency monotone in load", 32, |g| {
        let m = LatencyModel {
            alpha: g.f64_in(1e-5, 1e-2),
            beta: g.f64_in(1e4, 1e9),
        };
        let d = g.usize_in(1, 20);
        let bytes = g.usize_in(1, 1_000_000) as u64;
        let t1 = m.round_time(d, bytes);
        assert!(t1 > 0.0);
        assert!(m.round_time(d + 1, bytes) >= t1);
        assert!(m.round_time(d, bytes * 2) >= t1);
        let r = g.usize_in(1, 50);
        assert!((m.rounds_time(r, d, bytes) - r as f64 * t1).abs() < 1e-9 * r as f64);
    });
}
