//! Property-based invariants over the coordinator substrates (the
//! offline-build stand-in for `proptest`, see `dssfn::testing`).

use dssfn::data::{shard_uniform, shard_weighted, SynthClassification};
use dssfn::linalg::Matrix;
use dssfn::network::{
    CommLedger, GossipEngine, LatencyModel, MixingMatrix, Topology, WeightRule,
};
use dssfn::testing::property;
use std::sync::Arc;

#[test]
fn mixing_matrices_doubly_stochastic_on_random_topologies() {
    property("mixing doubly stochastic", 24, |g| {
        let m = g.usize_in(2, 24);
        let topo = if g.bool_with(0.5) {
            let dmax = Topology::max_circular_degree(m).max(1);
            Topology::Circular { nodes: m, degree: g.usize_in(1, dmax) }
        } else {
            Topology::RandomGeometric {
                nodes: m,
                radius: g.f64_in(0.15, 0.6),
                seed: g.case() as u64,
            }
        };
        let rule = match topo {
            Topology::Circular { .. } => WeightRule::EqualNeighbor,
            _ => WeightRule::Metropolis,
        };
        let mix = MixingMatrix::build(&topo, rule).unwrap();
        // validate() ran inside build; re-check the eigen bound here.
        assert!(mix.lambda2() < 1.0 + 1e-9, "λ2 = {}", mix.lambda2());
        // consensus_rounds must be monotone in delta.
        assert!(mix.consensus_rounds(1e-12) >= mix.consensus_rounds(1e-2));
    });
}

#[test]
fn gossip_preserves_sum_and_contracts() {
    property("gossip conservation + contraction", 16, |g| {
        let m = g.usize_in(3, 16);
        let dmax = Topology::max_circular_degree(m).max(1);
        let d = g.usize_in(1, dmax);
        let rows = g.usize_in(1, 6);
        let cols = g.usize_in(1, 6);
        let mix = MixingMatrix::build(
            &Topology::Circular { nodes: m, degree: d },
            WeightRule::EqualNeighbor,
        )
        .unwrap();
        let engine =
            GossipEngine::new(mix, Arc::new(CommLedger::new()), LatencyModel::default());
        let mut vals: Vec<Matrix> = (0..m).map(|_| g.matrix(rows, cols, 3.0)).collect();
        let avg = GossipEngine::exact_average(&vals).unwrap();
        let before: f64 = vals.iter().map(|v| v.as_slice().iter().sum::<f64>()).sum();
        let err0: f64 = vals
            .iter()
            .map(|v| v.max_abs_diff(&avg))
            .fold(0.0, f64::max);
        engine.mix_rounds(&mut vals, 12).unwrap();
        let after: f64 = vals.iter().map(|v| v.as_slice().iter().sum::<f64>()).sum();
        assert!(
            (before - after).abs() < 1e-8 * (1.0 + before.abs()),
            "sum drift"
        );
        let err1: f64 = vals
            .iter()
            .map(|v| v.max_abs_diff(&avg))
            .fold(0.0, f64::max);
        assert!(err1 <= err0 + 1e-12, "consensus error grew: {err0} -> {err1}");
    });
}

#[test]
fn sharding_partitions_every_sample_exactly_once() {
    property("shard partition", 16, |g| {
        let q = g.usize_in(2, 5);
        let j = g.usize_in(20, 120);
        let m = g.usize_in(1, j.min(12));
        let task = {
            let mut s = SynthClassification::with_shape("p", g.usize_in(2, 10), q, j, 10);
            s.seed = g.case() as u64;
            s.generate().unwrap()
        };
        let shards = if g.bool_with(0.5) {
            shard_uniform(&task.train, m).unwrap()
        } else {
            let w: Vec<f64> = (0..m).map(|_| g.f64_in(0.2, 3.0)).collect();
            shard_weighted(&task.train, &w).unwrap()
        };
        let total: usize = shards.iter().map(|s| s.num_samples()).sum();
        assert_eq!(total, j);
        // Column-exact reconstruction in order.
        let mut col = 0usize;
        for sh in &shards {
            for c in 0..sh.num_samples() {
                assert_eq!(sh.labels[c], task.train.labels[col]);
                for r in 0..task.train.input_dim() {
                    assert_eq!(sh.x.get(r, c), task.train.x.get(r, col));
                }
                col += 1;
            }
        }
    });
}

#[test]
fn frobenius_projection_is_projection() {
    property("P_eps is a metric projection", 32, |g| {
        let rows = g.usize_in(1, 8);
        let cols = g.usize_in(1, 8);
        let eps = g.f64_in(0.1, 10.0);
        let z = g.matrix(rows, cols, 4.0);
        let mut p = z.clone();
        p.project_frobenius(eps);
        // Feasible.
        assert!(p.frobenius_norm() <= eps + 1e-9);
        // Idempotent.
        let mut pp = p.clone();
        pp.project_frobenius(eps);
        assert!(pp.max_abs_diff(&p) < 1e-12);
        // Non-expansive toward any feasible point (here: scaled-down z).
        let mut feasible = z.clone();
        feasible.project_frobenius(eps * 0.5);
        let dz = z.sub(&feasible).unwrap().frobenius_norm();
        let dp = p.sub(&feasible).unwrap().frobenius_norm();
        assert!(dp <= dz + 1e-9);
    });
}

#[test]
fn cholesky_solve_residuals_bounded() {
    property("cholesky solves SPD systems", 24, |g| {
        let n = g.usize_in(1, 40);
        let ridge = g.f64_in(0.5, 5.0) + n as f64 * 0.1;
        let a = g.spd(n, ridge);
        let f = a.cholesky().unwrap();
        let x_true = g.matrix(3, n, 2.0);
        let b = x_true.matmul(&a).unwrap();
        let x = f.solve_xa(&b).unwrap();
        assert!(
            x.max_abs_diff(&x_true) < 1e-6,
            "n={n} err {}",
            x.max_abs_diff(&x_true)
        );
    });
}

#[test]
fn latency_model_monotonicity() {
    property("latency monotone in load", 32, |g| {
        let m = LatencyModel {
            alpha: g.f64_in(1e-5, 1e-2),
            beta: g.f64_in(1e4, 1e9),
        };
        let d = g.usize_in(1, 20);
        let bytes = g.usize_in(1, 1_000_000) as u64;
        let t1 = m.round_time(d, bytes);
        assert!(t1 > 0.0);
        assert!(m.round_time(d + 1, bytes) >= t1);
        assert!(m.round_time(d, bytes * 2) >= t1);
        let r = g.usize_in(1, 50);
        assert!((m.rounds_time(r, d, bytes) - r as f64 * t1).abs() < 1e-9 * r as f64);
    });
}
