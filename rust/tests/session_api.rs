//! Integration: the resumable `TrainSession` step API — builder
//! validation, typed event streams, stop-policy budgets, and the
//! plateau-to-growth lowering.

use dssfn::session::{SessionBuilder, StepEvent, StopPolicy, StopReason};
use dssfn::ssfn::GrowthPolicy;
use dssfn::{DecentralizedTrainer, ExperimentConfig};

fn tiny_builder() -> SessionBuilder {
    SessionBuilder::new()
        .dataset("quickstart")
        .seed(3)
        .layers(2)
        .hidden_extra(12)
        .admm_iterations(5)
        .nodes(4)
        .degree(1)
        .threads(2)
}

#[test]
fn event_stream_shape_matches_configuration() {
    let mut session = tiny_builder().build().unwrap();
    let mut events = Vec::new();
    while let Some(ev) = session.step().unwrap() {
        events.push(ev);
    }
    // L=2 → 3 layer solves (input solve + 2 layers), K=5 each.
    let prepared = events.iter().filter(|e| matches!(e, StepEvent::LayerPrepared { .. })).count();
    let iters = events.iter().filter(|e| matches!(e, StepEvent::AdmmIteration { .. })).count();
    let advanced = events.iter().filter(|e| matches!(e, StepEvent::LayerAdvanced { .. })).count();
    let gossip = events.iter().filter(|e| matches!(e, StepEvent::GossipRound { .. })).count();
    assert_eq!(prepared, 3);
    assert_eq!(iters, 3 * 5);
    assert_eq!(advanced, 3);
    assert_eq!(gossip, 3 * 5, "one averaging per gossip-mode iteration");
    assert!(matches!(
        events.last(),
        Some(StepEvent::Finished { reason: StopReason::Completed })
    ));
    // Every gossip event charges traffic.
    for ev in &events {
        if let StepEvent::GossipRound { rounds, bytes, .. } = ev {
            assert!(*rounds > 0);
            assert!(*bytes > 0);
        }
    }
    let (model, report) = session.finish().unwrap();
    let model = model.into_ssfn().unwrap();
    assert_eq!(model.weights().len(), 2);
    assert_eq!(report.layers.len(), 3);
}

#[test]
fn exact_consensus_sessions_emit_no_gossip_events() {
    let mut session = tiny_builder().exact_consensus().build().unwrap();
    let mut gossip = 0;
    while let Some(ev) = session.step().unwrap() {
        if matches!(ev, StepEvent::GossipRound { .. }) {
            gossip += 1;
        }
        if let StepEvent::AdmmIteration { consensus_gap, .. } = ev {
            assert_eq!(consensus_gap, 0.0, "exact averaging keeps nodes identical");
        }
    }
    assert_eq!(gossip, 0);
}

#[test]
fn observer_hooks_and_progress_counters_fire() {
    use std::cell::RefCell;
    let counts = RefCell::new((0usize, 0usize));
    let mut session = tiny_builder().build().unwrap();
    session.observe_fn(|ev| {
        let mut c = counts.borrow_mut();
        match ev {
            StepEvent::AdmmIteration { .. } => c.0 += 1,
            StepEvent::LayerAdvanced { .. } => c.1 += 1,
            _ => {}
        }
    });
    assert_eq!(session.progress().comm_bytes, 0);
    let (_, report) = session.finish().unwrap();
    drop(session); // release the observer's borrow of `counts`
    let (iters, layers) = counts.into_inner();
    assert_eq!(iters, 3 * 5);
    assert_eq!(layers, 3);
    assert!(report.comm_total.bytes > 0);
}

#[test]
fn simulated_time_budget_truncates_inside_layer_one() {
    // A vanishing time budget trips on the very first event; layer 0
    // still completes (the model needs one structured weight), then
    // layer 1 truncates after a single iteration.
    let session = tiny_builder()
        .build()
        .unwrap()
        .with_policy(StopPolicy::none().with_max_simulated_secs(1e-9))
        .unwrap();
    let mut session = session;
    let mut reason = None;
    while let Some(ev) = session.step().unwrap() {
        if let StepEvent::Finished { reason: r } = ev {
            reason = Some(r);
        }
    }
    assert_eq!(reason, Some(StopReason::BudgetSimTime));
    let (model, report) = session.finish().unwrap();
    let model = model.into_ssfn().unwrap();
    assert_eq!(report.layers.len(), 2, "layer 0 full + truncated layer 1");
    assert_eq!(report.layers[0].iterations(), 5);
    assert_eq!(report.layers[1].iterations(), 1);
    assert_eq!(model.weights().len(), 1);
    // The truncated model still classifies.
    assert!(report.train_accuracy > 0.25);
}

#[test]
fn builder_plateau_lowers_onto_growth_bit_identically() {
    // The StopPolicy cost-plateau clause must reproduce the legacy
    // train_task_with_growth stop point and model exactly.
    let threshold = 0.9;
    let mut session = SessionBuilder::new()
        .dataset("quickstart")
        .seed(3)
        .layers(4)
        .hidden_extra(20)
        .admm_iterations(20)
        .nodes(4)
        .degree(1)
        .threads(2)
        .stop_policy(StopPolicy::none().with_min_layer_improvement(threshold))
        .build()
        .unwrap();
    let mut finished = None;
    while let Some(ev) = session.step().unwrap() {
        if let StepEvent::Finished { reason } = ev {
            finished = Some(reason);
        }
    }
    let (m_session, r_session) = session.finish().unwrap();
    let m_session = m_session.into_ssfn().unwrap();

    let mut cfg = ExperimentConfig::named_dataset("quickstart").unwrap();
    cfg.seed = 3;
    cfg.layers = 4;
    cfg.hidden_extra = 20;
    cfg.admm_iterations = 20;
    cfg.nodes = 4;
    cfg.degree = 1;
    cfg.threads = 2;
    let task = cfg.generate_task().unwrap();
    let trainer = DecentralizedTrainer::from_config(&cfg).unwrap();
    let (m_legacy, r_legacy) = trainer
        .train_task_with_growth(&task, GrowthPolicy { min_relative_improvement: threshold })
        .unwrap();

    assert_eq!(m_session.weights().len(), m_legacy.weights().len());
    for (a, b) in m_session.weights().iter().zip(m_legacy.weights()) {
        assert_eq!(a.max_abs_diff(b), 0.0);
    }
    assert_eq!(m_session.output().max_abs_diff(m_legacy.output()), 0.0);
    assert_eq!(r_session.full_cost_curve(), r_legacy.full_cost_curve());
    if m_session.weights().len() < 4 {
        // Growth actually stopped early → the session reports it.
        assert_eq!(finished, Some(StopReason::GrowthStopped));
    }
}

#[test]
fn request_stop_truncates_and_reports_requested() {
    let mut session = tiny_builder().admm_iterations(50).build().unwrap();
    // Let layer 0 start, then ask for a stop.
    for _ in 0..5 {
        session.step().unwrap();
    }
    session.request_stop();
    let mut reason = None;
    while let Some(ev) = session.step().unwrap() {
        if let StepEvent::Finished { reason: r } = ev {
            reason = Some(r);
        }
    }
    assert_eq!(reason, Some(StopReason::Requested));
    let (model, report) = session.finish().unwrap();
    let model = model.into_ssfn().unwrap();
    assert_eq!(model.weights().len(), 1);
    assert!(report.layers.len() < 3);
}

#[test]
fn checkpoint_after_finish_is_rejected() {
    let mut session = tiny_builder().build().unwrap();
    session.finish().unwrap();
    assert!(session.checkpoint().is_err());
}
