//! Integration: the `dssfn` CLI binary.

use std::process::Command;

fn dssfn() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dssfn"))
}

#[test]
fn datasets_lists_table1() {
    let out = dssfn().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for key in ["vowel", "satimage", "caltech101", "letter", "norb", "mnist"] {
        assert!(text.contains(key), "missing {key} in:\n{text}");
    }
    assert!(text.contains("60000")); // mnist train size
}

#[test]
fn info_shows_resolved_config() {
    let out = dssfn()
        .args(["info", "--dataset", "letter-small", "--degree", "3"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("letter-small"));
    assert!(text.contains("degree=3"));
    assert!(text.contains("Q=26"));
}

#[test]
fn train_quickstart_native_runs() {
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "2",
            "--admm-iters",
            "15",
            "--nodes",
            "4",
            "--degree",
            "1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("train"), "no summary in:\n{text}");
    assert!(text.contains("gossip rounds"));
}

#[test]
fn central_quickstart_runs() {
    let out = dssfn()
        .args([
            "central",
            "--dataset",
            "quickstart",
            "--layers",
            "2",
            "--admm-iters",
            "15",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("centralized"));
}

#[test]
fn bad_flags_fail_gracefully() {
    let out = dssfn().args(["train", "--dataset", "nope"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown dataset"), "stderr: {err}");

    let out = dssfn().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());

    let out = dssfn().args(["train", "--degree"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn train_checkpoint_and_resume_roundtrip() {
    let dir = std::env::temp_dir().join(format!("dssfn_cli_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("state.ckpt");
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "2",
            "--admm-iters",
            "8",
            "--nodes",
            "4",
            "--degree",
            "1",
            "--checkpoint",
        ])
        .arg(&ckpt)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(ckpt.exists(), "no checkpoint written");
    // Resume from the snapshot: regenerates the checkpoint's dataset and
    // replays the remaining layers.
    let out = dssfn().args(["train", "--resume"]).arg(&ckpt).output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("gossip rounds"), "no summary in:\n{text}");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("resuming"), "stderr: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_semisync_schedule_and_adaptive_delta() {
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "1",
            "--admm-iters",
            "10",
            "--nodes",
            "4",
            "--degree",
            "1",
            "--schedule",
            "semisync",
            "--staleness",
            "2",
            "--adaptive-delta",
            "1e-4",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("semisync(s=2)"), "schedule missing from mode:\n{text}");
    assert!(text.contains("adaptive"), "adaptive tag missing from mode:\n{text}");

    // Unknown schedule names fail fast.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--schedule", "psync"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown schedule"));

    // Schedule flags conflict with --resume (the checkpoint carries the
    // run's configuration).
    let out = dssfn()
        .args(["train", "--resume", "nope.ckpt", "--schedule", "lossy"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot be combined"));
}

#[test]
fn unused_comm_flags_are_rejected_not_ignored() {
    // --staleness under the default sync schedule used to be a silent
    // no-op; now it fails fast with a pointer at the right schedule.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--staleness", "3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("semisync"), "stderr: {err}");

    // --loss-p without the lossy schedule, same story.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--loss-p", "0.2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("lossy"));

    // Cross-pairing: --staleness with the lossy schedule.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--schedule", "lossy", "--staleness", "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("semisync"));

    // --adaptive-delta under exact consensus.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--exact-consensus",
            "--adaptive-delta", "1e-4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("exact_consensus"), "stderr: {err}");

    // --iter-staleness refuses a relaxed fabric schedule.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--schedule", "semisync",
            "--iter-staleness", "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("staleness"));

    // --adaptive-period rides --adaptive-delta.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--adaptive-period", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("adaptive_delta"));

    // The `info` command surfaces the same validation — it never prints
    // a configuration `train` would refuse.
    let out = dssfn()
        .args(["info", "--dataset", "quickstart", "--staleness", "3"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let out = dssfn()
        .args([
            "info", "--dataset", "quickstart", "--exact-consensus",
            "--iter-staleness", "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("exact_consensus"));
    // ... and a valid combination prints the full fabric line.
    let out = dssfn()
        .args([
            "info", "--dataset", "quickstart", "--iter-staleness", "2",
            "--straggler-sigma", "0.5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iter-stale(s=2)"), "{text}");
    assert!(text.contains("straggler(σ=0.5)"), "{text}");
}

#[test]
fn transport_flags_validate() {
    // Transport flags conflict with --resume like every training flag.
    for args in [
        ["train", "--resume", "nope.ckpt", "--bind", "127.0.0.1:0"],
        ["train", "--resume", "nope.ckpt", "--shard", "1"],
        ["train", "--resume", "nope.ckpt", "--min-clients", "2"],
    ] {
        let out = dssfn().args(args).output().unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("cannot be combined"), "stderr: {err}");
    }

    // Communication schedules are seeded math over the share bank and
    // run identically over the wire — serve/worker accept them. The
    // probes fail *past* transport validation on a later, named check
    // (shard range for worker, quorum range for serve), proving the
    // schedule itself was not refused.
    for sched_flags in [
        ["--schedule", "semisync"],
        ["--schedule", "lossy"],
        ["--adaptive-delta", "1e-4"],
        ["--iter-staleness", "2"],
    ] {
        let out = dssfn()
            .args([
                "worker", "--connect", "127.0.0.1:1", "--shard", "99",
                "--dataset", "quickstart",
            ])
            .args(sched_flags)
            .output()
            .unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            !err.contains("simulation-only"),
            "{sched_flags:?} wrongly rejected as simulation-only: {err}"
        );
        assert!(err.contains("out of range"), "stderr: {err}");

        let out = dssfn()
            .args([
                "serve", "--bind", "127.0.0.1:0", "--min-clients", "99",
                "--dataset", "quickstart",
            ])
            .args(sched_flags)
            .output()
            .unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            !err.contains("simulation-only"),
            "{sched_flags:?} wrongly rejected as simulation-only: {err}"
        );
        assert!(err.contains("exceeds the cluster size"), "stderr: {err}");
    }

    // What stays simulation-only is the faked cluster physics: the
    // straggler model, crash-injection chaos and the event clock. Each
    // is refused by name before any socket work.
    let out = dssfn()
        .args([
            "worker", "--connect", "127.0.0.1:1", "--shard", "0",
            "--dataset", "quickstart", "--straggler-sigma", "0.5",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("simulation-only"), "stderr: {err}");
    assert!(err.contains("--straggler-sigma"), "stderr: {err}");
    let out = dssfn()
        .args([
            "worker", "--connect", "127.0.0.1:1", "--shard", "0",
            "--dataset", "quickstart", "--chaos-crash-p", "0.1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("simulation-only"), "stderr: {err}");
    assert!(err.contains("--chaos-crash-p"), "stderr: {err}");
    let out = dssfn()
        .args([
            "serve", "--bind", "127.0.0.1:0", "--dataset", "quickstart",
            "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("simulation-only"), "stderr: {err}");
    assert!(err.contains("--clock event"), "stderr: {err}");
    let out = dssfn()
        .args([
            "serve", "--bind", "127.0.0.1:0", "--dataset", "quickstart",
            "--exact-consensus",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("gossip consensus"));

    // Missing required transport flags fail fast.
    let out = dssfn().args(["serve", "--dataset", "quickstart"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--bind"));
    let out = dssfn()
        .args(["worker", "--connect", "127.0.0.1:1", "--dataset", "quickstart"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--shard"));

    // A shard outside 0..M is refused before connecting anywhere.
    let out = dssfn()
        .args([
            "worker", "--connect", "127.0.0.1:1", "--shard", "99",
            "--dataset", "quickstart",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("out of range"));
}

#[test]
fn compress_flag_matrix() {
    // A quantized run trains end to end and reports the compressor in
    // its mode line.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--layers", "1",
            "--admm-iters", "8", "--nodes", "4", "--degree", "1",
            "--compress", "q4",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compress=q4"), "compressor missing from mode:\n{text}");

    // Malformed and out-of-range spellings fail at flag-parse time.
    for bad in ["zip", "q0", "q9", "topk:0", "topk:1.5"] {
        let out = dssfn()
            .args(["train", "--dataset", "quickstart", "--compress", bad])
            .output()
            .unwrap();
        assert!(!out.status.success(), "--compress {bad} accepted");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("compress"), "--compress {bad}: {err}");
    }

    // Exact averaging exchanges no messages to compress.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--exact-consensus",
            "--compress", "q4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("exact_consensus"));

    // Chaos churn would orphan the per-edge error-feedback state.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--chaos-crash-p", "0.1",
            "--compress", "q4",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fault injection"));

    // --compress conflicts with --resume like every training flag (the
    // checkpoint carries the compressor and its accumulators).
    let out = dssfn()
        .args(["train", "--resume", "nope.ckpt", "--compress", "q4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot be combined"));

    // Compression is seeded math inside the server's gossip engine and
    // runs identically over the wire: serve/worker accept it. The
    // probes fail *past* transport validation on a later, named check,
    // proving the compressor itself was not refused.
    for spec in ["q4", "topk:0.1"] {
        let out = dssfn()
            .args([
                "worker", "--connect", "127.0.0.1:1", "--shard", "99",
                "--dataset", "quickstart", "--compress", spec,
            ])
            .output()
            .unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            !err.contains("simulation-only"),
            "--compress {spec} wrongly rejected as simulation-only: {err}"
        );
        assert!(err.contains("out of range"), "stderr: {err}");

        let out = dssfn()
            .args([
                "serve", "--bind", "127.0.0.1:0", "--min-clients", "99",
                "--dataset", "quickstart", "--compress", spec,
            ])
            .output()
            .unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(
            !err.contains("simulation-only"),
            "--compress {spec} wrongly rejected as simulation-only: {err}"
        );
        assert!(err.contains("exceeds the cluster size"), "stderr: {err}");
    }

    // info surfaces the compressor in the fabric line.
    let out = dssfn()
        .args(["info", "--dataset", "quickstart", "--compress", "topk:0.1"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("compress=topk:0.1"));
}

/// The committed `docs/CLI.md` is exactly what the binary generates —
/// the flag table, the usage text and the doc share one source, so they
/// cannot drift.
#[test]
fn cli_doc_matches_committed_reference() {
    let out = dssfn().arg("cli-doc").output().unwrap();
    assert!(out.status.success());
    let generated = String::from_utf8(out.stdout).unwrap();
    assert_eq!(generated, dssfn::clidoc::markdown());
    let committed = std::fs::read_to_string(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/CLI.md"),
    )
    .unwrap();
    assert_eq!(
        committed, generated,
        "docs/CLI.md is stale; regenerate with `cargo run --release -- cli-doc > docs/CLI.md`"
    );
}

#[test]
fn straggler_corr_and_iter_schedule_flags() {
    // --straggler-corr rides --straggler-sigma.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--straggler-corr", "0.5"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("straggler_sigma"));

    // --iter-schedule shapes are validated at parse time...
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--iter-schedule", "sometimes"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("iter_schedule"));

    // ... and a non-default schedule rides --iter-staleness.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--iter-schedule", "fixed:2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("iter_staleness"));

    // info prints the full fabric line for a valid combination.
    let out = dssfn()
        .args([
            "info", "--dataset", "quickstart", "--iter-staleness", "2",
            "--iter-schedule", "oneslow:1:2", "--straggler-sigma", "0.5",
            "--straggler-corr", "0.8",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("one-slow(node=1, lag=2)"), "{text}");
    assert!(text.contains("straggler(σ=0.5, ρ=0.8)"), "{text}");

    // A fixed-lag run trains end to end and reports its schedule.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--layers", "1",
            "--admm-iters", "8", "--nodes", "4", "--degree", "1",
            "--iter-staleness", "2", "--iter-schedule", "fixed:1",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fixed-lag(1)"), "{text}");
}

#[test]
fn chaos_flags_validate_and_train() {
    // Every chaos knob that would be a silent no-op without a crash
    // probability is rejected with a pointer at --chaos-crash-p.
    for args in [
        ["train", "--dataset", "quickstart", "--chaos-seed", "7"],
        ["train", "--dataset", "quickstart", "--chaos-rejoin-p", "0.5"],
        ["train", "--dataset", "quickstart", "--min-nodes", "2"],
    ] {
        let out = dssfn().args(args).output().unwrap();
        assert!(!out.status.success());
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("chaos_crash_p"), "stderr: {err}");
    }

    // Quorum bounds: 0 and > M are both refused.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--nodes", "4",
            "--chaos-crash-p", "0.1", "--min-nodes", "0",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("min_nodes"));
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--nodes", "4",
            "--chaos-crash-p", "0.1", "--min-nodes", "5",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("min_nodes"));

    // Fault injection needs gossip: exact consensus refuses it ...
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--exact-consensus",
            "--chaos-crash-p", "0.1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("exact_consensus"));

    // ... and so does iteration staleness (frozen state has no age).
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--iter-staleness", "2",
            "--chaos-crash-p", "0.1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("staleness"));

    // Chaos knobs conflict with --resume like every training flag.
    let out = dssfn()
        .args(["train", "--resume", "nope.ckpt", "--chaos-crash-p", "0.1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot be combined"));

    // A churn run trains end to end and reports its mode. Degree 2 on
    // 4 nodes is the complete graph, so no crash pattern can disconnect
    // the live set.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--layers", "1",
            "--admm-iters", "8", "--nodes", "4", "--degree", "2",
            "--chaos-crash-p", "0.15", "--chaos-rejoin-p", "0.6",
            "--chaos-seed", "11", "--min-nodes", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        text.contains("chaos(p=0.15, rejoin=0.6, quorum=2)"),
        "chaos tag missing from mode:\n{text}"
    );
}

#[test]
fn clock_flag_validates_and_trains() {
    // Unknown engine names fail at flag-parse time.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--clock", "wall"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("clock"));

    // The event engine schedules per-node gossip rounds: exact
    // consensus, lossy gossip and fault injection all refuse it.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--exact-consensus",
            "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("exact_consensus"));
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--schedule", "lossy",
            "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("lossy"));
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--chaos-crash-p", "0.1",
            "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("fault injection"));

    // --clock conflicts with --resume like every training flag, and is
    // simulation-only under the wire transport.
    let out = dssfn()
        .args(["train", "--resume", "nope.ckpt", "--clock", "event"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot be combined"));
    let out = dssfn()
        .args([
            "worker", "--connect", "127.0.0.1:1", "--shard", "0",
            "--dataset", "quickstart", "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("simulation-only"));

    // info surfaces the engine in the fabric line ...
    let out = dssfn()
        .args(["info", "--dataset", "quickstart", "--clock", "event"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("clock=event"));

    // ... and an event-clock run trains end to end, reporting its mode.
    let out = dssfn()
        .args([
            "train", "--dataset", "quickstart", "--layers", "1",
            "--admm-iters", "8", "--nodes", "4", "--degree", "1",
            "--straggler-sigma", "0.5", "--straggler-seed", "7",
            "--clock", "event",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("clock=event"), "mode missing clock=event:\n{text}");
}

#[test]
fn train_with_iter_staleness_and_straggler_model() {
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "1",
            "--admm-iters",
            "10",
            "--nodes",
            "4",
            "--degree",
            "1",
            "--iter-staleness",
            "2",
            "--straggler-sigma",
            "0.5",
            "--straggler-seed",
            "7",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("iter-stale(s=2)"), "mode missing iter-stale:\n{text}");
    assert!(text.contains("straggler"), "mode missing straggler:\n{text}");
}

#[test]
fn train_checkpoint_every_iterations_and_resume() {
    let dir = std::env::temp_dir().join(format!("dssfn_cli_ckpt_every_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("mid.ckpt");
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "1",
            "--admm-iters",
            "9",
            "--nodes",
            "4",
            "--degree",
            "1",
            "--checkpoint-every",
            "4",
            "--verbose",
            "--checkpoint",
        ])
        .arg(&ckpt)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(
        err.contains("checkpoint at layer"),
        "no per-iteration checkpoint logged: {err}"
    );
    assert!(ckpt.exists());
    // The mid-layer snapshot resumes cleanly.
    let out = dssfn().args(["train", "--resume"]).arg(&ckpt).output().unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );

    // --checkpoint-every without --checkpoint (or with 0) is refused.
    let out = dssfn()
        .args(["train", "--dataset", "quickstart", "--checkpoint-every", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("needs --checkpoint"));
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--checkpoint-every",
            "0",
            "--checkpoint",
        ])
        .arg(&ckpt)
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_with_byte_budget_stops_early_and_verbose_streams_events() {
    let out = dssfn()
        .args([
            "train",
            "--dataset",
            "quickstart",
            "--layers",
            "3",
            "--admm-iters",
            "10",
            "--nodes",
            "4",
            "--degree",
            "1",
            "--max-bytes",
            "1",
            "--verbose",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("event:"), "verbose events missing: {err}");
    assert!(err.contains("BudgetBytes"), "budget stop missing: {err}");
}

#[test]
fn sweep_writes_csv() {
    let dir = std::env::temp_dir().join(format!("dssfn_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("sweep.csv");
    let out = dssfn()
        .args([
            "sweep",
            "--dataset",
            "quickstart",
            "--layers",
            "1",
            "--admm-iters",
            "10",
            "--nodes",
            "6",
            "--degrees",
            "1,3",
            "--csv",
        ])
        .arg(&csv)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let body = std::fs::read_to_string(&csv).unwrap();
    assert!(body.starts_with("degree,"));
    assert_eq!(body.lines().count(), 3); // header + 2 degrees
    std::fs::remove_dir_all(&dir).ok();
}
