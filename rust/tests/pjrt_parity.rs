//! Integration: PJRT artifact execution vs the native f64 oracle.
//!
//! Requires `make artifacts` (the tests are skipped with a notice when
//! the quickstart artifacts are missing, so `cargo test` stays green on
//! a fresh checkout).

use dssfn::admm::LocalSolve;
use dssfn::config::{BackendKind, ExperimentConfig};
use dssfn::coordinator::DecentralizedTrainer;
use dssfn::linalg::Matrix;
use dssfn::runtime::{ArtifactManifest, ComputeBackend, NativeBackend, PjrtBackend};
use dssfn::util::{Rng, Xoshiro256StarStar};

fn backend() -> Option<PjrtBackend> {
    let manifest = ArtifactManifest::load("artifacts").ok()?;
    match PjrtBackend::start(&manifest, "quickstart") {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("skipping pjrt parity ({e}); run `make artifacts`");
            None
        }
    }
}

fn rand_mat(rng: &mut impl Rng, rows: usize, cols: usize, mag: f64) -> Matrix {
    Matrix::from_fn(rows, cols, |_, _| rng.uniform(-mag, mag))
}

#[test]
fn forward_gram_update_output_parity() {
    let Some(be) = backend() else { return };
    let native = NativeBackend::new();
    let cfg = be.config().clone();
    let mut rng = Xoshiro256StarStar::seed_from_u64(11);
    let (p, q, n, j) = (cfg.p, cfg.q, cfg.n, cfg.j);

    // Forward through both layer shapes, with an under-filled shard to
    // exercise the zero-padding path.
    let w1 = rand_mat(&mut rng, n, p, 1.0);
    let x = rand_mat(&mut rng, p, j - 3, 1.0);
    let a = be.layer_forward(&w1, &x).unwrap();
    let b = native.layer_forward(&w1, &x).unwrap();
    assert_eq!(a.shape(), (n, j - 3));
    assert!(a.max_abs_diff(&b) < 1e-4, "first_forward {}", a.max_abs_diff(&b));

    let wn = rand_mat(&mut rng, n, n, 0.3);
    let y = {
        let mut y = native.layer_forward(&w1, &x).unwrap();
        y.relu_inplace();
        y
    };
    let a = be.layer_forward(&wn, &y).unwrap();
    let b = native.layer_forward(&wn, &y).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-3 * (1.0 + b.frobenius_norm()));

    // Solver parity through several ADMM iterations.
    let t = rand_mat(&mut rng, q, j - 3, 1.0);
    let sp = be.prepare_layer(&y, &t, 1.0).unwrap();
    let sn = native.prepare_layer(&y, &t, 1.0).unwrap();
    let mut z = Matrix::zeros(q, n);
    let mut lam = Matrix::zeros(q, n);
    for k in 0..5 {
        let op = sp.o_update(&z, &lam).unwrap();
        let on = sn.o_update(&z, &lam).unwrap();
        let scale = 1.0 + on.frobenius_norm();
        assert!(
            op.max_abs_diff(&on) < 2e-3 * scale,
            "iter {k}: o diff {}",
            op.max_abs_diff(&on)
        );
        let (cp, cn) = (sp.cost(&on).unwrap(), sn.cost(&on).unwrap());
        assert!((cp - cn).abs() < 1e-2 * (1.0 + cn), "cost {cp} vs {cn}");
        z = on.clone();
        z.project_frobenius(2.0 * q as f64);
        lam.axpy(1.0, &on).unwrap();
        lam.axpy(-1.0, &z).unwrap();
    }

    // Scores.
    let o = rand_mat(&mut rng, q, n, 0.5);
    let a = be.output_scores(&o, &y).unwrap();
    let b = native.output_scores(&o, &y).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-3 * (1.0 + b.frobenius_norm()));
}

#[test]
fn full_training_parity_native_vs_pjrt() {
    if backend().is_none() {
        return;
    }
    let mut cfg = ExperimentConfig::named_dataset("quickstart").unwrap();
    cfg.layers = 3;
    cfg.admm_iterations = 40;
    cfg.nodes = 10;
    cfg.degree = 2;

    cfg.backend = BackendKind::Native;
    let (_, rn) = DecentralizedTrainer::run_config(&cfg).unwrap();
    cfg.backend = BackendKind::Pjrt;
    let (_, rp) = DecentralizedTrainer::run_config(&cfg).unwrap();

    // f32 artifacts vs f64 natives: performance metrics must agree.
    assert!(
        (rn.train_accuracy - rp.train_accuracy).abs() < 0.03,
        "train {} vs {}",
        rn.train_accuracy,
        rp.train_accuracy
    );
    assert!(
        (rn.test_accuracy - rp.test_accuracy).abs() < 0.05,
        "test {} vs {}",
        rn.test_accuracy,
        rp.test_accuracy
    );
    for (ln, lp) in rn.layers.iter().zip(&rp.layers) {
        let (a, b) = (ln.final_cost().unwrap(), lp.final_cost().unwrap());
        assert!(
            (a - b).abs() <= 0.03 * a.max(1e-9) + 1e-3,
            "layer {} cost {a} vs {b}",
            ln.layer
        );
    }
    // Identical communication pattern regardless of backend.
    assert_eq!(rn.total_gossip_rounds(), rp.total_gossip_rounds());
    assert_eq!(rn.comm_total.bytes, rp.comm_total.bytes);
}

#[test]
fn backend_handles_are_shareable_across_threads() {
    let Some(be) = backend() else { return };
    let cfg = be.config().clone();
    let be = std::sync::Arc::new(be);
    let mut handles = Vec::new();
    for i in 0..4u64 {
        let be = std::sync::Arc::clone(&be);
        let (p, n, j) = (cfg.p, cfg.n, cfg.j);
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256StarStar::seed_from_u64(i);
            let w = rand_mat(&mut rng, n, p, 1.0);
            let x = rand_mat(&mut rng, p, j, 1.0);
            let out = be.layer_forward(&w, &x).unwrap();
            assert_eq!(out.shape(), (n, j));
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
