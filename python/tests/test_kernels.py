"""L1 kernel correctness: Pallas vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (including degenerate 1-row/1-col cases and
sizes straddling the tile boundaries) and magnitudes; every kernel must
match its oracle to f32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gram, matmul, matmul_relu, o_update
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=40)
SEED = st.integers(min_value=0, max_value=2**31 - 1)
SCALE = st.floats(min_value=0.01, max_value=100.0)

COMMON = dict(deadline=None, max_examples=25)


def _rand(rng, *shape, scale=1.0):
    return (rng.normal(size=shape) * scale).astype(np.float32)


def _assert_close(a, b, scale=1.0):
    np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4 * max(scale, 1.0) ** 2
    )


class TestMatmulRelu:
    @settings(**COMMON)
    @given(m=DIM, k=DIM, n=DIM, seed=SEED, scale=SCALE)
    def test_matches_ref(self, m, k, n, seed, scale):
        rng = np.random.default_rng(seed)
        w = _rand(rng, m, k, scale=scale)
        y = _rand(rng, k, n)
        _assert_close(matmul_relu(w, y), ref.matmul_relu_ref(w, y), scale)

    @settings(**COMMON)
    @given(m=DIM, k=DIM, n=DIM, seed=SEED)
    def test_matmul_without_relu(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        w = _rand(rng, m, k)
        y = _rand(rng, k, n)
        _assert_close(matmul(w, y), w @ y)

    def test_relu_clamps_negatives(self):
        w = np.array([[1.0], [-1.0]], dtype=np.float32)
        y = np.array([[2.0, -3.0]], dtype=np.float32)
        out = np.asarray(matmul_relu(w, y))
        assert (out >= 0.0).all()
        np.testing.assert_allclose(out, [[2.0, 0.0], [0.0, 3.0]])

    def test_tile_boundary_shapes(self):
        # Exactly one tile, one tile + 1, and one tile - 1.
        rng = np.random.default_rng(0)
        for m in (127, 128, 129):
            w = _rand(rng, m, 130)
            y = _rand(rng, 130, 64)
            _assert_close(matmul_relu(w, y), ref.matmul_relu_ref(w, y))

    def test_zero_columns_stay_zero(self):
        # The rust runtime relies on zero-padding neutrality.
        rng = np.random.default_rng(1)
        w = _rand(rng, 16, 8)
        y = np.zeros((8, 5), dtype=np.float32)
        assert np.abs(np.asarray(matmul_relu(w, y))).max() == 0.0


class TestGram:
    @settings(**COMMON)
    @given(n=DIM, q=DIM, j=DIM, seed=SEED, mu_inv=st.floats(0.0, 50.0))
    def test_matches_ref(self, n, q, j, seed, mu_inv):
        rng = np.random.default_rng(seed)
        y = _rand(rng, n, j)
        t = _rand(rng, q, j)
        g, c = gram(y, t, np.float32(mu_inv))
        gr, cr = ref.gram_ref(y, t, np.float32(mu_inv))
        _assert_close(g, gr)
        _assert_close(c, cr)

    def test_gram_is_symmetric_spd(self):
        rng = np.random.default_rng(2)
        y = _rand(rng, 20, 50)
        g, _ = gram(y, _rand(rng, 3, 50), np.float32(1.0))
        g = np.asarray(g)
        np.testing.assert_allclose(g, g.T, rtol=1e-6)
        assert np.linalg.eigvalsh(g).min() > 0.9  # ridge keeps it PD

    def test_padding_neutrality(self):
        # Zero sample columns must not change either Gram.
        rng = np.random.default_rng(3)
        y = _rand(rng, 10, 33)
        t = _rand(rng, 4, 33)
        yp = np.pad(y, ((0, 0), (0, 31)))
        tp = np.pad(t, ((0, 0), (0, 31)))
        g1, c1 = gram(y, t, np.float32(0.5))
        g2, c2 = gram(yp, tp, np.float32(0.5))
        _assert_close(g1, g2)
        _assert_close(c1, c2)

    def test_spans_multiple_j_blocks(self):
        rng = np.random.default_rng(4)
        y = _rand(rng, 12, 700)  # > 2 × BJ=256
        t = _rand(rng, 3, 700)
        g, c = gram(y, t, np.float32(2.0))
        gr, cr = ref.gram_ref(y, t, np.float32(2.0))
        _assert_close(g, gr)
        _assert_close(c, cr)


class TestOUpdate:
    @settings(**COMMON)
    @given(q=DIM, n=DIM, seed=SEED, mu_inv=st.floats(0.0, 50.0))
    def test_matches_ref(self, q, n, seed, mu_inv):
        rng = np.random.default_rng(seed)
        tyt = _rand(rng, q, n)
        z = _rand(rng, q, n)
        lam = _rand(rng, q, n)
        ginv = _rand(rng, n, n)
        _assert_close(
            o_update(tyt, z, lam, ginv, np.float32(mu_inv)),
            ref.o_update_ref(tyt, z, lam, ginv, np.float32(mu_inv)),
        )

    def test_mu_zero_reduces_to_plain_matmul(self):
        rng = np.random.default_rng(5)
        tyt = _rand(rng, 4, 20)
        ginv = _rand(rng, 20, 20)
        z = _rand(rng, 4, 20)
        out = o_update(tyt, z, z, ginv, np.float32(0.0))
        _assert_close(out, tyt @ ginv)

    def test_spans_multiple_n_blocks(self):
        rng = np.random.default_rng(6)
        q, n = 3, 600  # > 2 × BN=256
        tyt, z, lam = (_rand(rng, q, n) for _ in range(3))
        ginv = _rand(rng, n, n) / n
        _assert_close(
            o_update(tyt, z, lam, ginv, np.float32(0.7)),
            ref.o_update_ref(tyt, z, lam, ginv, np.float32(0.7)),
        )


class TestProjection:
    @settings(**COMMON)
    @given(q=DIM, n=DIM, seed=SEED, eps=st.floats(0.1, 20.0))
    def test_projection_feasible_and_idempotent(self, q, n, seed, eps):
        rng = np.random.default_rng(seed)
        z = _rand(rng, q, n, scale=5.0)
        p1 = np.asarray(ref.project_frobenius_ref(z, np.float32(eps)))
        assert np.linalg.norm(p1) <= eps * (1 + 1e-5)
        p2 = np.asarray(ref.project_frobenius_ref(p1, np.float32(eps)))
        np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)

    def test_inside_ball_untouched(self):
        z = np.ones((2, 2), dtype=np.float32)  # norm 2
        out = np.asarray(ref.project_frobenius_ref(z, np.float32(10.0)))
        np.testing.assert_array_equal(out, z)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
