"""L2 model graph tests: gram_inverse accuracy, entrypoint shapes, and a
full in-python dSSFN layer-solve sanity check stitched from the same
functions the AOT artifacts are lowered from.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, model

COMMON = dict(deadline=None, max_examples=15)


class TestGramInverse:
    @settings(**COMMON)
    @given(
        n=st.integers(2, 64),
        ridge=st.floats(0.05, 20.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_inverse_accuracy(self, n, ridge, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(n, n)).astype(np.float32)
        g = a @ a.T / n + ridge * np.eye(n, dtype=np.float32)
        inv = np.asarray(model.gram_inverse(g))
        resid = np.abs(inv @ g - np.eye(n)).max()
        assert resid < 5e-4, f"residual {resid}"

    def test_matches_numpy_inverse(self):
        rng = np.random.default_rng(0)
        a = rng.normal(size=(30, 30)).astype(np.float32)
        g = a @ a.T / 30 + np.eye(30, dtype=np.float32)
        inv = np.asarray(model.gram_inverse(g))
        np.testing.assert_allclose(inv, np.linalg.inv(g), rtol=2e-3, atol=2e-4)


class TestEntrypoints:
    def test_shapes_and_count(self):
        eps = aot.entrypoints(p=12, q=4, n=108, j=20)
        names = [e[0] for e in eps]
        assert names == [
            "first_forward",
            "forward",
            "gram_p",
            "gram_n",
            "inv_p",
            "inv_n",
            "o_update_p",
            "o_update_n",
            "output",
        ]
        # Executable with zero inputs of the declared shapes.
        for name, fn, specs in eps:
            args = [np.zeros(s.shape, dtype=np.float32) for s in specs]
            out = fn(*args)
            assert out is not None, name

    def test_configs_cover_small_registry(self):
        names = {c[0] for c in aot.configs(full=False)}
        assert "quickstart" in names
        assert {"mnist-small", "letter-small"} <= names
        full_names = {c[0] for c in aot.configs(full=True)}
        assert {"mnist", "caltech101"} <= full_names
        # n = 2Q + hidden_extra and j = ceil(J/M) invariants.
        for name, p, q, n, j in aot.configs(full=False):
            assert n > 2 * q, name
            assert j >= 1

    def test_hlo_text_has_no_custom_calls(self):
        # xla_extension 0.5.1 cannot compile typed-FFI custom calls; the
        # whole artifact set must stay within native HLO.
        import jax

        for name, fn, specs in aot.entrypoints(p=6, q=3, n=10, j=8):
            text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
            assert "custom-call" not in text, f"{name} contains a custom call"


class TestLayerSolveEndToEnd:
    def test_python_admm_layer_matches_lstsq(self):
        """Stitch gram → inverse → o_update into the ADMM loop and check
        it solves the (unconstrained) least squares to good accuracy —
        the same composition the rust coordinator executes via PJRT."""
        rng = np.random.default_rng(7)
        n, q, j = 24, 3, 80
        y = rng.normal(size=(n, j)).astype(np.float32)
        t = rng.normal(size=(q, j)).astype(np.float32)
        mu_inv = np.float32(1.0)
        g, tyt = model.gram(y, t, mu_inv)
        ginv = model.gram_inverse(np.asarray(g))
        z = np.zeros((q, n), dtype=np.float32)
        lam = np.zeros((q, n), dtype=np.float32)
        eps = np.float32(1e6)  # never binds
        from compile.kernels.ref import project_frobenius_ref

        for _ in range(300):
            o = np.asarray(model.o_update(tyt, z, lam, ginv, mu_inv))
            z = np.asarray(project_frobenius_ref(o + lam, eps))
            lam = lam + o - z
        expect = np.linalg.solve(
            (y @ y.T).astype(np.float64), (t @ y.T).astype(np.float64).T
        ).T
        np.testing.assert_allclose(z, expect, rtol=5e-3, atol=5e-3)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
