"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for rust.

Run as ``python -m compile.aot --out-dir ../artifacts [--full]`` (this is
what ``make artifacts`` does). For every shape configuration it lowers
the nine entrypoints of :mod:`compile.model` and writes
``<out>/<config>/<entry>.hlo.txt`` plus a ``manifest.txt`` the rust
runtime parses (``rust/src/runtime/artifact.rs``).

HLO **text** is the interchange format: jax ≥ 0.5 serializes
``HloModuleProto`` with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` rust crate) rejects; the text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True`` — the rust side unwraps with ``to_tuple()``.

Shape configurations mirror ``rust/src/data/registry.rs`` +
``rust/src/config.rs`` defaults: ``n = 2Q + hidden_extra`` and
``j = ceil(J_train / M)`` (the padded per-shard width; rust zero-pads
smaller shards, which is exactly neutral through every kernel).
"""

import argparse
import math
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (key, P, Q, J_train) mirrored from rust/src/data/registry.rs.
_TABLE1 = [
    ("vowel", 10, 11, 528),
    ("satimage", 36, 6, 4435),
    ("caltech101", 3000, 102, 6000),
    ("letter", 16, 26, 13333),
    ("norb", 2048, 5, 24300),
    ("mnist", 784, 10, 60000),
]
_SMALL = [
    ("vowel-small", 10, 11, 264),
    ("satimage-small", 36, 6, 600),
    ("caltech101-small", 128, 102, 2040),
    ("letter-small", 16, 26, 1000),
    ("norb-small", 96, 5, 1000),
    ("mnist-small", 64, 10, 2000),
    ("quickstart", 12, 4, 200),
]

# Defaults matching ExperimentConfig::named_dataset.
_FULL_HIDDEN_EXTRA, _FULL_NODES = 1000, 20
_SMALL_HIDDEN_EXTRA, _SMALL_NODES = 100, 10


def configs(full=False):
    """Yield ``(name, p, q, n, j)`` for every configuration to build."""
    out = []
    for name, p, q, jtrain in _SMALL:
        n = 2 * q + _SMALL_HIDDEN_EXTRA
        out.append((name, p, q, n, math.ceil(jtrain / _SMALL_NODES)))
    if full:
        for name, p, q, jtrain in _TABLE1:
            n = 2 * q + _FULL_HIDDEN_EXTRA
            out.append((name, p, q, n, math.ceil(jtrain / _FULL_NODES)))
    return out


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entrypoints(p, q, n, j):
    """The nine (name, fn, example_args) triples for one configuration."""
    scalar = _spec()
    return [
        ("first_forward", model.layer_forward, (_spec(n, p), _spec(p, j))),
        ("forward", model.layer_forward, (_spec(n, n), _spec(n, j))),
        ("gram_p", model.gram, (_spec(p, j), _spec(q, j), scalar)),
        ("gram_n", model.gram, (_spec(n, j), _spec(q, j), scalar)),
        ("inv_p", model.gram_inverse, (_spec(p, p),)),
        ("inv_n", model.gram_inverse, (_spec(n, n),)),
        (
            "o_update_p",
            model.o_update,
            (_spec(q, p), _spec(q, p), _spec(q, p), _spec(p, p), scalar),
        ),
        (
            "o_update_n",
            model.o_update,
            (_spec(q, n), _spec(q, n), _spec(q, n), _spec(n, n), scalar),
        ),
        ("output", model.output_scores, (_spec(q, n), _spec(n, j))),
    ]


def build(out_dir, full=False, only=None, verbose=True):
    """Lower all configurations into ``out_dir``; returns manifest path."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = ["# dssfn artifact manifest v1"]
    for name, p, q, n, j in configs(full):
        if only and name not in only:
            continue
        cfg_dir = os.path.join(out_dir, name)
        os.makedirs(cfg_dir, exist_ok=True)
        for entry, fn, args in entrypoints(p, q, n, j):
            path = os.path.join(cfg_dir, f"{entry}.hlo.txt")
            text = to_hlo_text(jax.jit(fn).lower(*args))
            with open(path, "w") as f:
                f.write(text)
            if verbose:
                print(f"  {path}  ({len(text) // 1024} KiB)", file=sys.stderr)
        manifest_lines.append(f"config {name} p={p} q={q} n={n} j={j}")
        if verbose:
            print(f"config {name}: p={p} q={q} n={n} j={j}", file=sys.stderr)
    manifest = os.path.join(out_dir, "manifest.txt")
    # Merge with any configs already present (e.g. small built first,
    # full added later).
    existing = {}
    if os.path.exists(manifest):
        for line in open(manifest):
            line = line.strip()
            if line.startswith("config "):
                existing[line.split()[1]] = line
    for line in manifest_lines[1:]:
        existing[line.split()[1]] = line
    with open(manifest, "w") as f:
        f.write("# dssfn artifact manifest v1\n")
        for key in sorted(existing):
            f.write(existing[key] + "\n")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--full",
        action="store_true",
        help="also build the full-size Table-I shapes (slow, large)",
    )
    ap.add_argument(
        "--only",
        nargs="*",
        default=None,
        help="restrict to the named configs",
    )
    args = ap.parse_args()
    manifest = build(args.out_dir, full=args.full, only=args.only)
    print(f"wrote {manifest}")


if __name__ == "__main__":
    main()
