"""L2 — the dSSFN compute graph, one jittable function per entrypoint.

These are the exact functions `python/compile/aot.py` lowers to HLO text
for the rust runtime. Each calls into the L1 Pallas kernels so the
kernels lower into the same HLO module. Parameter order here **is** the
ABI the rust side (`rust/src/runtime/pjrt.rs`) relies on:

========= =====================================  =======================
entry     parameters (in order)                  outputs (tupled)
========= =====================================  =======================
forward   ``w (n,d)``, ``y (d,j)``               ``relu(w@y) (n,j)``
gram      ``y (d,j)``, ``t (q,j)``, ``mu_inv``   ``g (d,d)``, ``tyt (q,d)``
inv       ``g (d,d)``                            ``g⁻¹ (d,d)``
o_update  ``tyt``, ``z``, ``lam`` ``(q,d)``,     ``o (q,d)``
          ``ginv (d,d)``, ``mu_inv ()``
output    ``o (q,n)``, ``y (n,j)``               ``o@y (q,j)``
========= =====================================  =======================
"""

import jax
import jax.numpy as jnp

from .kernels import gram as _gram
from .kernels import matmul, matmul_relu, o_update as _o_update


def layer_forward(w, y):
    """``g(W·Y)`` — SSFN layer forward (L1 kernel ``matmul_relu``)."""
    return matmul_relu(w, y)


def gram(y, t, mu_inv):
    """Layer-constant ADMM Grams (L1 kernel ``gram``)."""
    return _gram(y, t, mu_inv)


NEWTON_SCHULZ_ITERS = 60


def gram_inverse(g):
    """Dense SPD inverse of the ridge-regularized Gram via Newton–Schulz.

    ``jnp.linalg.inv`` lowers to a LAPACK typed-FFI custom call on CPU,
    which xla_extension 0.5.1 (behind the rust ``xla`` crate) cannot
    compile — and which a TPU couldn't run either. Newton–Schulz
    iteration ``X ← X(2I − G X)`` is pure matmul HLO, quadratically
    convergent, and MXU-friendly. The classic initialization
    ``X₀ = Gᵀ/(‖G‖₁·‖G‖_∞)`` guarantees ‖I − X₀G‖ < 1 for any
    nonsingular ``G``; our ``G`` is SPD (ridge-regularized Gram), for
    which convergence is monotone. 60 iterations reach f32 roundoff for
    condition numbers ≳10⁶ beyond anything the μ-ridge admits.

    This is a one-per-layer ``n³`` op — hoisting it out of the ADMM loop
    is the optimization that matters (DESIGN.md §Perf).
    """
    n = g.shape[0]
    eye2 = 2.0 * jnp.eye(n, dtype=g.dtype)
    norm1 = jnp.max(jnp.sum(jnp.abs(g), axis=0))
    norminf = jnp.max(jnp.sum(jnp.abs(g), axis=1))
    x0 = g.T / (norm1 * norminf)

    def body(_, x):
        return x @ (eye2 - g @ x)

    return jax.lax.fori_loop(0, NEWTON_SCHULZ_ITERS, body, x0)


def o_update(tyt, z, lam, ginv, mu_inv):
    """Per-iteration ADMM O-update (L1 kernel ``admm_update``)."""
    return _o_update(tyt, z, lam, ginv, mu_inv)


def output_scores(o, y):
    """Prediction scores ``O·Y`` (L1 ``matmul``, no activation)."""
    return matmul(o, y, apply_relu=False)
