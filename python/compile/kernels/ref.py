"""Pure-jnp reference oracles for the L1 Pallas kernels.

Every Pallas kernel in this package has an exact mathematical twin here;
pytest (python/tests/) asserts allclose between the two across a
hypothesis-driven sweep of shapes and magnitudes. The rust native ``f64``
path is in turn tested against the PJRT execution of the lowered HLO
(rust/tests/pjrt_parity.rs), closing the three-way verification loop::

    pallas kernel  ==  jnp oracle  ==  rust f64 linalg
"""

import jax.numpy as jnp


def matmul_relu_ref(w, y, *, apply_relu=True):
    """``relu(W @ Y)`` — the SSFN layer forward ``g(W·Y)``."""
    out = w @ y
    if apply_relu:
        out = jnp.maximum(out, 0.0)
    return out


def gram_ref(y, t, mu_inv):
    """``(Y·Yᵀ + μ⁻¹·I, T·Yᵀ)`` — the layer-constant ADMM Grams."""
    n = y.shape[0]
    g = y @ y.T + mu_inv * jnp.eye(n, dtype=y.dtype)
    tyt = t @ y.T
    return g, tyt


def o_update_ref(tyt, z, lam, ginv, mu_inv):
    """``(T·Yᵀ + μ⁻¹(Z − Λ)) @ G⁻¹`` — ADMM step 1 (paper eq. 11)."""
    return (tyt + mu_inv * (z - lam)) @ ginv


def project_frobenius_ref(z, eps):
    """``P_ε(Z)``: rescale onto the Frobenius ball iff outside (eq. 11)."""
    norm = jnp.linalg.norm(z)
    scale = jnp.where(norm > eps, eps / jnp.maximum(norm, 1e-30), 1.0)
    return z * scale
