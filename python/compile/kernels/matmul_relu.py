"""MXU-tiled matmul with fused ReLU epilogue — the SSFN layer forward.

The paper's hot spot at every layer is ``Y_{l+1} = g(W_{l+1}·Y_l)``.
On TPU this maps onto the 128×128 MXU; the kernel tiles the output into
``(BM, BN)`` VMEM blocks and streams the contraction dimension in ``BK``
chunks via the grid so arbitrary `K` never has to fit in VMEM at once.

Hardware-adaptation notes (DESIGN.md §Hardware-Adaptation):

* block sizes are 128-multiples clamped to the problem so small layers
  don't waste VMEM;
* the ReLU epilogue runs on the block while it is still resident — no
  second HBM pass (what a CUDA port would do with a separate kernel);
* ``f32`` accumulation in the output block across the K-grid dimension
  (the grid's last axis is sequential, so `+=` accumulates safely);
* VMEM footprint per step: ``BM·BK + BK·BN + BM·BN`` f32 words — at the
  default 128³ tiles that is 192 KiB, well inside the ~16 MiB VMEM.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; on-TPU this code lowers unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (MXU-aligned).
BM, BN, BK = 128, 128, 128


def _matmul_kernel(w_ref, y_ref, o_ref, *, apply_relu, k_steps):
    """One (BM, BN) output block; grid = (m/BM, n/BN, k/BK)."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    if apply_relu:
        @pl.when(kb == k_steps - 1)
        def _epilogue():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0)


def _pad_to(x, rows, cols):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("apply_relu", "bm", "bn", "bk"))
def matmul(w, y, *, apply_relu=False, bm=BM, bn=BN, bk=BK):
    """``W @ Y`` (optionally fused with ReLU) via the Pallas kernel.

    Shapes: ``w (M, K)``, ``y (K, N)`` → ``(M, N)``. Inputs are padded to
    tile multiples and the result sliced back, so any shape works.
    """
    m, k = w.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_ = min(bm, max(8, m))
    bn_ = min(bn, max(8, n))
    bk_ = min(bk, max(8, k))
    mp = pl.cdiv(m, bm_) * bm_
    np_ = pl.cdiv(n, bn_) * bn_
    kp = pl.cdiv(k, bk_) * bk_
    wp = _pad_to(w.astype(jnp.float32), mp, kp)
    yp = _pad_to(y.astype(jnp.float32), kp, np_)
    k_steps = kp // bk_

    out = pl.pallas_call(
        functools.partial(
            _matmul_kernel, apply_relu=apply_relu, k_steps=k_steps
        ),
        grid=(mp // bm_, np_ // bn_, k_steps),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(wp, yp)
    return out[:m, :n]


def matmul_relu(w, y, **kw):
    """``relu(W @ Y)`` — the layer forward ``g(W·Y)``."""
    return matmul(w, y, apply_relu=True, **kw)
