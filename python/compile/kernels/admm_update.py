"""Fused ADMM O-update kernel: ``O = (C + μ⁻¹(Z − Λ)) @ G⁻¹``.

This runs once per node per ADMM iteration — `M·K·(L+1)` times per
training run, the most frequently executed kernel in the system. The
Gram inverse ``G⁻¹`` is loop-invariant (hoisted per layer, see
``model.gram_inverse``), so the iteration cost is one ``(q, n)×(n, n)``
matmul with the affine combination fused into the prologue: the ``A``
block is built in VMEM from ``C``, ``Z``, ``Λ`` tiles and multiplied
against the resident ``G⁻¹`` tile without ever materializing ``A`` in
HBM.

Grid: 1-D over output-column blocks (``q`` is small — 5..102 across the
paper's datasets — so rows always fit one block). The contraction reads
the same ``A`` row-strip every step; with ``q ≤ 128`` that strip stays
in VMEM across steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256


def _o_update_kernel(c_ref, z_ref, lam_ref, ginv_ref, mu_ref, o_ref):
    # A = C + μ⁻¹(Z − Λ): built in VMEM, fused into the matmul prologue.
    a = c_ref[...] + mu_ref[0, 0] * (z_ref[...] - lam_ref[...])
    o_ref[...] = jnp.dot(a, ginv_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bn",))
def o_update(tyt, z, lam, ginv, mu_inv, *, bn=BN):
    """ADMM step 1 (paper eq. 11) for ``tyt/z/lam (q, n)``, ``ginv (n, n)``.

    ``mu_inv`` is a scalar HLO parameter (traced), reshaped to a (1, 1)
    SMEM-style block for the kernel.
    """
    q, n = tyt.shape
    assert z.shape == (q, n) and lam.shape == (q, n)
    assert ginv.shape == (n, n)
    bn_ = min(bn, max(8, n))
    np_ = pl.cdiv(n, bn_) * bn_
    pad = ((0, 0), (0, np_ - n))
    padg = ((0, np_ - n), (0, np_ - n))
    mu = jnp.asarray(mu_inv, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _o_update_kernel,
        grid=(np_ // bn_,),
        in_specs=[
            pl.BlockSpec((q, np_), lambda jb: (0, 0)),
            pl.BlockSpec((q, np_), lambda jb: (0, 0)),
            pl.BlockSpec((q, np_), lambda jb: (0, 0)),
            pl.BlockSpec((np_, bn_), lambda jb: (0, jb)),
            pl.BlockSpec((1, 1), lambda jb: (0, 0)),
        ],
        out_specs=pl.BlockSpec((q, bn_), lambda jb: (0, jb)),
        out_shape=jax.ShapeDtypeStruct((q, np_), jnp.float32),
        interpret=True,
    )(
        jnp.pad(tyt.astype(jnp.float32), pad),
        jnp.pad(z.astype(jnp.float32), pad),
        jnp.pad(lam.astype(jnp.float32), pad),
        jnp.pad(ginv.astype(jnp.float32), padg),
        mu,
    )
    return out[:, :n]
