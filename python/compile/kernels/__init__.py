"""L1 Pallas kernels for the dSSFN hot path.

Three kernels cover the compute-dominant steps of Algorithm 1:

* :mod:`.matmul_relu` — the SSFN layer forward ``g(W·Y)`` as an MXU-tiled
  matmul with a fused ReLU epilogue;
* :mod:`.gram` — one streaming pass over the local features producing both
  ``Y·Yᵀ + μ⁻¹I`` and ``T·Yᵀ`` (halves HBM traffic on ``Y``);
* :mod:`.admm_update` — the per-iteration O-update
  ``(T·Yᵀ + μ⁻¹(Z−Λ))·G⁻¹`` as one epilogue-fused matmul.

All kernels run under ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); on a real TPU the same BlockSpecs map tiles
onto VMEM and the contractions onto the 128×128 MXU. ``ref.py`` holds the
pure-jnp oracles the kernels are verified against.
"""

from .admm_update import o_update
from .gram import gram
from .matmul_relu import matmul, matmul_relu

__all__ = ["matmul", "matmul_relu", "gram", "o_update"]
