"""Fused Gram kernel: one streaming pass over the local features
producing both ``G = Y·Yᵀ + μ⁻¹I`` and ``C = T·Yᵀ``.

This is the layer-constant precompute of the ADMM solve (paper eq. 11):
``G`` is inverted once per layer, ``C`` feeds every O-update. Computing
both in one pass reads ``Y`` from HBM once instead of twice — on the
sample-dimension sizes dSSFN sees (`J_m` in the thousands, `n ≈ 1k`) the
pass over ``Y`` *is* the memory bill, so the fusion halves it.

Grid layout: 1-D over ``J`` blocks (sequential), both outputs map every
step to the same full block and accumulate. VMEM per step:
``(n + q)·BJ + n² + q·n`` f32 words — for ``n = 1020, q = 10, BJ = 256``
about 4.3 MiB, inside VMEM. For much larger ``n`` the output would tile
over an extra grid axis; unnecessary at dSSFN scales (documented
roofline in DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BJ = 256


def _gram_kernel(y_ref, t_ref, g_ref, c_ref):
    jb = pl.program_id(0)

    @pl.when(jb == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    yb = y_ref[...]  # (n, BJ) resident once, used twice
    g_ref[...] += jnp.dot(yb, yb.T, preferred_element_type=jnp.float32)
    c_ref[...] += jnp.dot(t_ref[...], yb.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bj",))
def gram(y, t, mu_inv, *, bj=BJ):
    """``(Y·Yᵀ + μ⁻¹·I, T·Yᵀ)`` for ``y (n, J)``, ``t (q, J)``.

    ``mu_inv`` may be a traced scalar (it is an HLO parameter in the AOT
    artifact — the same compiled kernel serves every μ).
    """
    n, j = y.shape
    q, j2 = t.shape
    assert j == j2, f"sample mismatch {j} vs {j2}"
    bj_ = min(bj, max(8, j))
    jp = pl.cdiv(j, bj_) * bj_
    ypad = jnp.pad(y.astype(jnp.float32), ((0, 0), (0, jp - j)))
    tpad = jnp.pad(t.astype(jnp.float32), ((0, 0), (0, jp - j)))

    g, c = pl.pallas_call(
        _gram_kernel,
        grid=(jp // bj_,),
        in_specs=[
            pl.BlockSpec((n, bj_), lambda jb: (0, jb)),
            pl.BlockSpec((q, bj_), lambda jb: (0, jb)),
        ],
        out_specs=[
            pl.BlockSpec((n, n), lambda jb: (0, 0)),
            pl.BlockSpec((q, n), lambda jb: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, n), jnp.float32),
            jax.ShapeDtypeStruct((q, n), jnp.float32),
        ],
        interpret=True,
    )(ypad, tpad)
    # Ridge added outside the kernel: O(n) work, keeps mu_inv a plain
    # scalar operand instead of an SMEM block.
    g = g + jnp.asarray(mu_inv, jnp.float32) * jnp.eye(n, dtype=jnp.float32)
    return g, c
